//! Sharded MPSC ingest lanes: the submit-side fix for the serving
//! stack's self-inflicted serial term.
//!
//! Before this module every `AllReduceService::submit` funneled through
//! one `Mutex<Option<Sender<Job>>>` held *across the channel send* — a
//! software analog of the paper's hidden serial terms (the δ
//! memory-access and ε incast costs the classic model never prices).
//! Under fleet load that one lock serializes every producer thread and
//! manufactures exactly the artificial arrival skew Proficz
//! (arXiv 1804.05349) shows reorders algorithm winners.
//!
//! [`IngestLanes`] shards the queue: `L` cache-line-padded lanes, each
//! its own `Mutex<VecDeque<T>>`, with producers hashed to a lane by
//! thread id. Producers on **distinct lanes never block each other** —
//! there is no global lock anywhere on the push path. The only shared
//! state is three atomics (`pending`, `closed`, `sleeping`) and a
//! doorbell (`door` + `bell`) the producer touches **only when the
//! consumer is actually parked**, so the uncontended hot path is one
//! lane lock + one atomic increment + one relaxed-cost atomic load.
//!
//! ## Wakeup protocol (eventcount)
//!
//! The consumer parks on the doorbell condvar only after setting
//! `sleeping = true` (under the door lock) and **re-checking**
//! `pending`/`closed`. Producers increment `pending` (SeqCst) *before*
//! loading `sleeping`; the consumer stores `sleeping` *before* loading
//! `pending`. SeqCst total order makes a missed wakeup impossible: if
//! the producer's increment isn't seen by the consumer's re-check, then
//! the consumer's `sleeping = true` store is ordered before the
//! producer's load, so the producer sees it and rings the bell.
//!
//! ## Shutdown: zero dropped jobs
//!
//! `close()` sets `closed` (SeqCst) and rings the bell. Producers check
//! `closed` **under their lane lock** before pushing, so any push that
//! was accepted is visible to a drain that locks the same lane
//! afterwards. The consumer, upon observing `Closed`, must keep
//! sweeping [`IngestLanes::drain_into`] **until a sweep returns 0** —
//! the mutex release/acquire edges then guarantee every accepted item
//! is collected, and every producer ordered after the final sweep
//! observes `closed == true` and receives [`IngestClosed`] (which the
//! service maps to the typed `ApiError::ServiceStopped`).
//!
//! ## Poison isolation
//!
//! A producer that panics while holding a *lane* lock poisons only that
//! lane: pushes to it return [`IngestClosed`], the consumer's drains
//! recover the inner queue via `into_inner`, and all other lanes keep
//! serving. This strictly improves on the old single-queue behavior,
//! where one poisoned submit lock took the whole service down.

use std::collections::hash_map::DefaultHasher;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::telemetry::hist::{HistSnapshot, LatencyHist};

/// Pad each lane to its own cache line so two producers hammering
/// adjacent lanes don't false-share (same idiom as the telemetry
/// histogram bins).
#[repr(align(64))]
struct CachePadded<T>(T);

/// Lane-plane health counters, shared between the lanes (which record)
/// and the service metrics (which render). All lock-free atomics off
/// the hot path's uncontended stride: the high-water mark is one
/// `fetch_max` per push, the doorbell counters tick only when the
/// consumer actually parks, and the drain histogram records once per
/// sweep, not per item.
#[derive(Debug, Default)]
pub struct IngestStats {
    /// Highest `pending` count ever observed right after a push — how
    /// deep the front door backed up at its worst.
    depth_hwm: AtomicU64,
    /// Times the consumer parked on the doorbell (idle periods).
    sleeps: AtomicU64,
    /// Times a producer rang the bell to wake a parked consumer.
    wakes: AtomicU64,
    /// Non-empty drain sweeps.
    drains: AtomicU64,
    /// Total items collected across all sweeps.
    drained_items: AtomicU64,
    /// Log2 histogram of per-sweep batch sizes. The bins hold **item
    /// counts, not nanoseconds** (`record_nanos(n)` abuses the log2
    /// binning; read quantiles back via [`IngestStatsSnapshot::drain_quantile`]).
    drain_hist: LatencyHist,
}

impl IngestStats {
    /// Plain-data copy of the counters.
    pub fn snapshot(&self) -> IngestStatsSnapshot {
        IngestStatsSnapshot {
            depth_hwm: self.depth_hwm.load(Ordering::Relaxed),
            sleeps: self.sleeps.load(Ordering::Relaxed),
            wakes: self.wakes.load(Ordering::Relaxed),
            drains: self.drains.load(Ordering::Relaxed),
            drained_items: self.drained_items.load(Ordering::Relaxed),
            drain_hist: self.drain_hist.snapshot(),
        }
    }
}

/// Plain-data copy of [`IngestStats`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct IngestStatsSnapshot {
    pub depth_hwm: u64,
    pub sleeps: u64,
    pub wakes: u64,
    pub drains: u64,
    pub drained_items: u64,
    pub drain_hist: HistSnapshot,
}

impl IngestStatsSnapshot {
    /// Mean items per non-empty drain sweep (0 when never drained).
    pub fn mean_drain(&self) -> f64 {
        if self.drains == 0 {
            0.0
        } else {
            self.drained_items as f64 / self.drains as f64
        }
    }

    /// A drain-batch-size quantile in **items** (`None` when no sweep
    /// has run): undoes the seconds scaling [`HistSnapshot::quantile`]
    /// applies, since the bins here hold item counts.
    pub fn drain_quantile(&self, q: f64) -> Option<f64> {
        Some(self.drain_hist.quantile(q)? * 1e9)
    }
}

/// Typed rejection: the lanes are closed (service stopping/stopped) or
/// the target lane is poisoned. Callers map this to their own stopped
/// error; the distinction is deliberately not exposed because the
/// remedy (stop submitting) is the same.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IngestClosed;

/// Outcome of [`IngestLanes::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IngestWait {
    /// Items are pending — drain now.
    Ready,
    /// The deadline passed with nothing pending.
    TimedOut,
    /// The lanes are closed. Keep draining until a sweep returns 0,
    /// then every accepted item has been collected.
    Closed,
}

/// Sharded multi-producer queue: `L` independent FIFO lanes plus a
/// doorbell for the single consumer. See the module docs for the
/// protocol.
pub struct IngestLanes<T> {
    lanes: Box<[CachePadded<Mutex<VecDeque<T>>>]>,
    /// Items pushed but not yet drained, across all lanes. Incremented
    /// by producers after a successful push, decremented by the
    /// consumer after a drain. SeqCst — see the wakeup protocol.
    pending: AtomicUsize,
    /// Once true, every push is rejected with [`IngestClosed`].
    closed: AtomicBool,
    /// Doorbell: the consumer parks on `bell` under `door`; producers
    /// take `door` only when `sleeping` says the consumer is parked.
    door: Mutex<()>,
    bell: Condvar,
    sleeping: AtomicBool,
    /// Health counters, shared out via [`Self::stats_handle`] so the
    /// service metrics render them without reaching into the lanes.
    stats: Arc<IngestStats>,
}

thread_local! {
    /// Cached per-thread lane token (0 = not yet computed; tokens are
    /// forced odd so 0 is never a valid token).
    static LANE_TOKEN: std::cell::Cell<u64> = std::cell::Cell::new(0);
}

impl<T> IngestLanes<T> {
    /// Build with `lanes` shards (clamped to at least 1; 1 reproduces
    /// the single-queue baseline for contention benchmarks).
    pub fn new(lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let lanes: Vec<CachePadded<Mutex<VecDeque<T>>>> = (0..lanes)
            .map(|_| CachePadded(Mutex::new(VecDeque::new())))
            .collect();
        IngestLanes {
            lanes: lanes.into_boxed_slice(),
            pending: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            door: Mutex::new(()),
            bell: Condvar::new(),
            sleeping: AtomicBool::new(false),
            stats: Arc::new(IngestStats::default()),
        }
    }

    /// Number of lanes.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Shared handle to the lane-plane health counters.
    pub fn stats_handle(&self) -> Arc<IngestStats> {
        self.stats.clone()
    }

    /// Snapshot of the lane-plane health counters.
    pub fn stats(&self) -> IngestStatsSnapshot {
        self.stats.snapshot()
    }

    /// The lane the calling thread hashes to. Stable for the lifetime
    /// of the thread (the hash token is cached thread-locally).
    pub fn lane_for_current_thread(&self) -> usize {
        let token = LANE_TOKEN.with(|t| {
            let mut v = t.get();
            if v == 0 {
                let mut h = DefaultHasher::new();
                std::thread::current().id().hash(&mut h);
                v = h.finish() | 1; // never 0, so the cache slot is unambiguous
                t.set(v);
            }
            v
        });
        (token % self.lanes.len() as u64) as usize
    }

    /// Push onto the calling thread's hashed lane.
    pub fn push(&self, item: T) -> Result<(), IngestClosed> {
        self.push_to(self.lane_for_current_thread(), item)
    }

    /// Push onto an explicit lane (tests and pinned producers).
    /// Rejects with [`IngestClosed`] if the lanes are closed or the
    /// lane is poisoned. The closed check happens **under the lane
    /// lock** — that ordering is what makes the final drain sweep
    /// complete (see module docs).
    pub fn push_to(&self, lane: usize, item: T) -> Result<(), IngestClosed> {
        let slot = &self.lanes[lane % self.lanes.len()];
        {
            let mut q = slot.0.lock().map_err(|_| IngestClosed)?;
            if self.closed.load(Ordering::SeqCst) {
                return Err(IngestClosed);
            }
            q.push_back(item);
            let depth = self.pending.fetch_add(1, Ordering::SeqCst) + 1;
            self.stats.depth_hwm.fetch_max(depth as u64, Ordering::Relaxed);
        }
        // Ring the bell only if the consumer is (or may be) parked.
        // SeqCst pairs with the consumer's sleeping-store / pending-load.
        if self.sleeping.load(Ordering::SeqCst) {
            let _door = self.door.lock().unwrap_or_else(|e| e.into_inner());
            self.stats.wakes.fetch_add(1, Ordering::Relaxed);
            self.bell.notify_all();
        }
        Ok(())
    }

    /// Close the lanes: every subsequent push is rejected, and the
    /// parked consumer (if any) is woken. Idempotent.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let _door = self.door.lock().unwrap_or_else(|e| e.into_inner());
        self.bell.notify_all();
    }

    /// Whether [`close`](Self::close) has been called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Drain every lane (in lane-index order, preserving each lane's
    /// FIFO order) into `out`. Returns the number of items drained.
    /// Poisoned lanes are recovered — their queued items are still
    /// collected, so a producer panic never drops accepted jobs.
    pub fn drain_into(&self, out: &mut Vec<T>) -> usize {
        let mut n = 0;
        for slot in self.lanes.iter() {
            let mut q = slot.0.lock().unwrap_or_else(|e| e.into_inner());
            n += q.len();
            out.extend(q.drain(..));
        }
        if n > 0 {
            self.pending.fetch_sub(n, Ordering::SeqCst);
            self.stats.drains.fetch_add(1, Ordering::Relaxed);
            self.stats.drained_items.fetch_add(n as u64, Ordering::Relaxed);
            self.stats.drain_hist.record_nanos(n as u64); // bins = items
        }
        n
    }

    /// Approximate number of undrained items (consumer-side hint for
    /// flush accounting; exact only from the consumer thread).
    pub fn pending_hint(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Consumer-side wait: block until items are pending, the lanes are
    /// closed, or `deadline` passes (`None` = wait forever). Spurious
    /// `Ready` returns are possible (another drain may have raced) —
    /// callers must tolerate a zero-item drain.
    pub fn wait(&self, deadline: Option<Instant>) -> IngestWait {
        loop {
            if self.pending.load(Ordering::SeqCst) > 0 {
                return IngestWait::Ready;
            }
            if self.closed.load(Ordering::SeqCst) {
                return IngestWait::Closed;
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return IngestWait::TimedOut;
                }
            }
            // Park: announce sleeping, then RE-CHECK before waiting —
            // a producer that missed the announcement is caught by the
            // re-check; one that saw it will take the door lock and
            // ring the bell, which we can't miss while holding `door`.
            let door = self.door.lock().unwrap_or_else(|e| e.into_inner());
            self.sleeping.store(true, Ordering::SeqCst);
            if self.pending.load(Ordering::SeqCst) > 0 || self.closed.load(Ordering::SeqCst) {
                self.sleeping.store(false, Ordering::SeqCst);
                continue;
            }
            self.stats.sleeps.fetch_add(1, Ordering::Relaxed);
            let _door = match deadline {
                None => self.bell.wait(door).unwrap_or_else(|e| e.into_inner()),
                Some(d) => {
                    let timeout = d.saturating_duration_since(Instant::now());
                    let (g, _res) = self
                        .bell
                        .wait_timeout(door, timeout)
                        .unwrap_or_else(|e| e.into_inner());
                    g
                }
            };
            self.sleeping.store(false, Ordering::SeqCst);
        }
    }

    /// Poison one lane's mutex (a thread panics while holding it) —
    /// test hook for the poison-isolation guarantees, used by both the
    /// unit tests here and the crate's integration stress tests. Not
    /// part of the API surface.
    #[doc(hidden)]
    pub fn poison_lane(&self, lane: usize) {
        let slot = &self.lanes[lane % self.lanes.len()];
        let res = std::thread::scope(|s| {
            s.spawn(|| {
                let _q = slot.0.lock().unwrap_or_else(|e| e.into_inner());
                panic!("poisoning lane on purpose");
            })
            .join()
        });
        assert!(res.is_err(), "poisoner thread must have panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn per_lane_fifo_and_no_loss() {
        let lanes = IngestLanes::new(4);
        for lane in 0..4usize {
            for seq in 0..10u64 {
                lanes.push_to(lane, (lane, seq)).unwrap();
            }
        }
        let mut out = Vec::new();
        assert_eq!(lanes.drain_into(&mut out), 40);
        assert_eq!(out.len(), 40);
        // drain order is lane-index order; within a lane, push order.
        let mut last: Vec<Option<u64>> = vec![None; 4];
        for (lane, seq) in &out {
            if let Some(prev) = last[*lane] {
                assert!(*seq > prev, "lane {lane} out of order");
            }
            last[*lane] = Some(*seq);
        }
        assert_eq!(lanes.pending_hint(), 0);
    }

    #[test]
    fn close_rejects_pushes_but_drains_accepted_items() {
        let lanes = IngestLanes::new(2);
        lanes.push_to(0, 1u32).unwrap();
        lanes.close();
        assert_eq!(lanes.push_to(1, 2u32), Err(IngestClosed));
        assert!(lanes.is_closed());
        assert_eq!(lanes.wait(None), IngestWait::Closed);
        let mut out = Vec::new();
        assert_eq!(lanes.drain_into(&mut out), 1);
        assert_eq!(out, vec![1]);
        assert_eq!(lanes.drain_into(&mut out), 0);
    }

    #[test]
    fn poisoned_lane_rejects_typed_while_other_lanes_serve() {
        let lanes = IngestLanes::new(3);
        lanes.push_to(0, 10u32).unwrap();
        lanes.poison_lane(0);
        assert_eq!(lanes.push_to(0, 11u32), Err(IngestClosed));
        // Other lanes are unaffected.
        lanes.push_to(1, 20u32).unwrap();
        lanes.push_to(2, 30u32).unwrap();
        // Drains recover the poisoned lane — the accepted item survives.
        let mut out = Vec::new();
        assert_eq!(lanes.drain_into(&mut out), 3);
        assert_eq!(out, vec![10, 20, 30]);
    }

    /// THE no-global-lock pin: a producer stalled on (or holding) one
    /// lane must not block a producer on a different lane. If any
    /// global lock sneaks back onto the push path this test deadlocks
    /// (the harness timeout turns that into a failure).
    #[test]
    fn producers_on_distinct_lanes_never_block_each_other() {
        let lanes = IngestLanes::new(2);
        // Hold lane 0's lock from this thread...
        let guard = lanes.lanes[0].0.lock().unwrap();
        // ...while another thread pushes to lane 1. It must complete.
        std::thread::scope(|s| {
            let h = s.spawn(|| lanes.push_to(1, 7u32));
            h.join().unwrap().unwrap();
        });
        drop(guard);
        let mut out = Vec::new();
        assert_eq!(lanes.drain_into(&mut out), 1);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn wait_deadline_times_out_when_idle() {
        let lanes = IngestLanes::<u8>::new(1);
        let d = Instant::now() + Duration::from_millis(20);
        assert_eq!(lanes.wait(Some(d)), IngestWait::TimedOut);
        assert!(Instant::now() >= d);
    }

    #[test]
    fn parked_consumer_is_woken_by_push_and_by_close() {
        let lanes = IngestLanes::new(1);
        std::thread::scope(|s| {
            let waiter = s.spawn(|| lanes.wait(None));
            std::thread::sleep(Duration::from_millis(10));
            lanes.push_to(0, 1u8).unwrap();
            assert_eq!(waiter.join().unwrap(), IngestWait::Ready);
        });
        let mut out = Vec::new();
        lanes.drain_into(&mut out);
        std::thread::scope(|s| {
            let waiter = s.spawn(|| lanes.wait(None));
            std::thread::sleep(Duration::from_millis(10));
            lanes.close();
            assert_eq!(waiter.join().unwrap(), IngestWait::Closed);
        });
    }

    #[test]
    fn stats_track_depth_sleeps_wakes_and_drain_sizes() {
        let lanes = IngestLanes::new(2);
        // 5 pushes with no drain: the high-water mark is the full depth.
        for i in 0..5u32 {
            lanes.push_to((i % 2) as usize, i).unwrap();
        }
        let mut out = Vec::new();
        assert_eq!(lanes.drain_into(&mut out), 5);
        // Second, smaller burst.
        lanes.push_to(0, 99).unwrap();
        assert_eq!(lanes.drain_into(&mut out), 1);
        let s = lanes.stats();
        assert_eq!(s.depth_hwm, 5);
        assert_eq!(s.drains, 2);
        assert_eq!(s.drained_items, 6);
        assert!((s.mean_drain() - 3.0).abs() < 1e-9);
        // Drain-size quantiles come back in items: the max sweep was 5
        // items (bin 2), the min 1 (bin 0); midpoints are within ×√2.
        let p = s.drain_quantile(1.0).unwrap();
        assert!(p > 3.9 && p < 5.7, "{p}");
        // Empty drains record nothing.
        assert_eq!(lanes.drain_into(&mut out), 0);
        assert_eq!(lanes.stats().drains, 2);
        // A parked consumer woken by a push ticks both doorbell counters.
        std::thread::scope(|s| {
            let waiter = s.spawn(|| lanes.wait(None));
            std::thread::sleep(Duration::from_millis(10));
            lanes.push_to(0, 1).unwrap();
            assert_eq!(waiter.join().unwrap(), IngestWait::Ready);
        });
        let s = lanes.stats();
        assert!(s.sleeps >= 1, "consumer parked at least once");
        assert!(s.wakes >= 1, "producer rang the bell");
    }

    #[test]
    fn empty_stats_report_zero_not_nonsense() {
        let lanes = IngestLanes::<u8>::new(1);
        let s = lanes.stats();
        assert_eq!(s.depth_hwm, 0);
        assert_eq!(s.mean_drain(), 0.0);
        assert_eq!(s.drain_quantile(0.95), None);
    }

    #[test]
    fn thread_hashing_is_stable_and_in_range() {
        let lanes = IngestLanes::<u8>::new(4);
        let a = lanes.lane_for_current_thread();
        let b = lanes.lane_for_current_thread();
        assert_eq!(a, b);
        assert!(a < 4);
    }
}
