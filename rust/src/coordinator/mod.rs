//! L3 coordinator — the serving layer around the executor.
//!
//! A deployment-shaped AllReduce service in the spirit of what DDP/
//! Horovod-style frameworks wrap around a collective library:
//!
//! * [`service`] — leader thread owning the job queue; clients submit
//!   per-worker tensors and receive results over channels;
//! * [`batcher`] — gradient bucketing: small tensors from concurrent jobs
//!   fuse into one AllReduce round (amortizing the α term — exactly the
//!   trade GenModel prices), flushed on size or time;
//! * [`router`] — plan cache: routes any registered `api::AlgoSpec`
//!   (GenTree by default), cached per `(algorithm, payload-size bucket)`
//!   and shared as `Arc<RoutedPlan>` on the hot path;
//! * [`metrics`] — atomic counters exposed for the CLI and benches.
//!
//! Threads + channels stand in for an async runtime (tokio is not in the
//! vendored dependency closure; the control flow is identical).

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod service;

pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{nearest_bucket, PlanRouter, RoutedPlan, SelectionRules};
pub use service::{AllReduceService, JobResult, ServiceConfig};
