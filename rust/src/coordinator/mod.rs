//! L3 coordinator — the serving layer around the executor.
//!
//! A deployment-shaped AllReduce service in the spirit of what DDP/
//! Horovod-style frameworks wrap around a collective library:
//!
//! * [`service`] — leader thread owning the job queue; clients submit
//!   per-worker tensors and receive results over channels;
//! * [`ingest`] — the sharded front door. Submits land on per-thread
//!   hashed MPSC lanes ([`ingest::IngestLanes`]): each lane is its own
//!   cache-line-padded lock, so producers on distinct lanes never block
//!   each other and there is **no global lock on the submit hot path**
//!   (the old single `Mutex<Sender>` serialized every submitter across
//!   the channel send — a self-inflicted serial term, exactly the δ/ε
//!   costs the paper says the classic model hides). The leader drains
//!   lanes in lane-index order (per-lane FIFO preserved), parks on an
//!   eventcount doorbell producers ring only when it actually sleeps,
//!   and on close keeps sweeping until a sweep returns empty — zero
//!   accepted jobs dropped. Draining and the epoch probe compose
//!   unchanged: the leader still reads one table view per flush cycle
//!   (top of cycle, after the drain), so hot swaps land between
//!   cycles with the same guarantees as before sharding. A poisoned
//!   lane (client panic mid-submit) degrades that lane's submitters to
//!   `ServiceStopped` while every other lane keeps serving;
//! * [`batcher`] — gradient bucketing: small tensors from concurrent jobs
//!   fuse into one AllReduce round (amortizing the α term — exactly the
//!   trade GenModel prices), flushed on size or time. With a campaign
//!   selection table wired in ([`ServiceConfig::with_selection_table`]),
//!   the batcher is **selection-aware**: a fuse stops at a router bucket
//!   boundary where the table's winner changes decisively (margin ≥
//!   `min_split_margin`), and every emitted batch reports the
//!   [`batcher::BatchRule`] that closed it;
//! * [`router`] — plan cache: routes any registered `api::AlgoSpec`
//!   (GenTree by default), cached per `(algorithm, payload-size bucket)`
//!   and shared as `Arc<RoutedPlan>` on the hot path;
//! * [`metrics`] — atomic counters exposed for the CLI and benches,
//!   including per-[`batcher::BatchRule`] split/fuse counts (summing to
//!   `batches_flushed` — the snapshot checks the invariant), the
//!   execution/e2e latency histograms with their per-stage lifecycle
//!   decomposition, the shared ingest-lane gauges, and the SLO trip
//!   counter (see the observability guide below).
//!
//! The serving loop is also a *measurement* loop: each executed batch's
//! observed seconds (wall-clock, or deterministic flow-simulated under
//! [`service::ObserveMode::Sim`]) land in the metrics histogram and —
//! when a [`crate::telemetry::Recorder`] is wired in
//! ([`ServiceConfig::with_telemetry`]) — in the per-(class, bucket,
//! algorithm) telemetry cells the `repro score` / `repro calibrate`
//! loop consumes. With a selection table configured, flushing is
//! **time-aware**: the flush window is capped per bucket at the
//! predicted round time the fuse would save
//! ([`batcher::BatchPolicy::flush_window`]), clamped below at
//! [`batcher::BatchPolicy::flush_floor`] so a tiny prediction can never
//! degenerate into busy-spin flushing.
//!
//! And with `ServiceConfig::drift` set, measurement closes back on the
//! policy — the **autopilot**:
//!
//! * [`handle`] — the selection table behind an epoch-versioned
//!   [`handle::TableHandle`] instead of frozen construction-time config.
//!   One [`handle::TableView`] bundles the epoch with all three derived
//!   consumers (router rules, batcher split points, time-aware flush
//!   windows); the leader reads the view once per flush cycle, so the
//!   consumers always observe the same epoch, and every [`JobResult`]
//!   reports the epoch (`JobResult::epoch`) that served it.
//! * [`drift`] — the [`drift::DriftMonitor`] runs in the leader between
//!   flush cycles: it scores the recorder's fresh observations against
//!   the active table's own predictions, and past
//!   `serve --drift-threshold` it recalibrates the offending (class,
//!   bucket) cells (§3.4 Calibrator when the data supports the fit, else
//!   a targeted re-price under the service's environment), merges them
//!   over the active table, and swaps atomically —
//!   [`PlanRouter::evict_stale`] drops cached plans whose winner was
//!   dethroned, and `drift_*` metrics count checks/swaps/evictions and
//!   expose the serving epoch. Because the swap happens between cycles
//!   on the leader thread, no job is ever dropped, duplicated, or served
//!   by a half-swapped policy.
//!
//! The handle is also the service's **external control surface**: an
//! outside controller holding [`AllReduceService::table_handle`] — the
//! [`crate::fleet`] registry is the in-tree consumer — may swap a
//! recalibrated table in at any time. The leader probes the handle's
//! epoch at the top of every flush cycle, so a cross-rack push lands
//! with exactly the same guarantees as a local drift swap: stale plans
//! evicted, consumers re-derived together, epochs reported, zero
//! dropped jobs.
//!
//! # Observability guide
//!
//! Every job is stamped at five points of its life — submit
//! (`Job::t_submit`), lane drain (after each [`IngestLanes`] drain
//! sweep), batch close (one stamp per flush cycle, after the batch
//! plan), execution start, and execution end — decomposing its latency
//! into **queued → drained → batched → executed**. The decomposition
//! rides every [`JobResult`] as [`service::JobStages`] (whose `e2e_ns`
//! is the *exact* structural sum of the four stages — pinned by
//! rust/tests/prop_lifecycle.rs), and every exported series traces back
//! to one of those stamp sites:
//!
//! * `allreduce_latency_seconds` ([`Metrics::exec_latency`]) — the
//!   batch's observed execution seconds, recorded when the executor
//!   returns. The family name predates the decomposition and stays
//!   pinned to the exec stage so existing dashboards keep their
//!   meaning; the client-visible tail is the e2e family below.
//! * `allreduce_e2e_latency_seconds` ([`Metrics::e2e_latency`]) — the
//!   per-job submit → respond total, recorded at respond time.
//! * `allreduce_stage_seconds{stage="queued"|"drained"|"batched"}`
//!   ([`Metrics::stage_queued`] / [`Metrics::stage_drained`] /
//!   [`Metrics::stage_batched`]) — the pre-execution stages. The same
//!   durations also land in the shared [`crate::telemetry::Recorder`]
//!   under sentinel algorithm keys `stage:*`, which
//!   [`crate::telemetry::CellKey::is_stage`] keeps out of every
//!   batch-latency aggregate the scoring/calibration loop reads.
//! * `allreduce_slo_trips_total` ([`Metrics::slo_trips`]) — burn-rate
//!   trips of the per-class [`crate::telemetry::SloTracker`] configured
//!   via [`service::ServiceConfig`]'s `slo` ([`crate::fleet::FleetSpec`]
//!   / `repro fleet --slo class=secs` upstream); each trip also emits
//!   one [`crate::trace::SpanKind::SloTrip`] span.
//! * `allreduce_ingest_depth_hwm`, `allreduce_ingest_sleeps_total`,
//!   `allreduce_ingest_wakes_total`, `allreduce_ingest_drain_jobs`
//!   ([`ingest::IngestStats`], shared into [`Metrics::ingest`]) — the
//!   lane-depth high-water mark, doorbell park/ring counters, and the
//!   drain-batch-size histogram, all instrumented inside
//!   [`IngestLanes`] itself.
//! * Trace spans `job_queued` / `job_drained` / `job_done`
//!   ([`crate::trace::SpanKind::JobQueued`] and friends) — the same
//!   stamps re-emitted as a per-job timeline for `repro trace
//!   --chrome`, with `job_done`'s duration equal to the job's e2e.
//!   `repro trace --check` (via
//!   [`crate::trace::TraceSnapshot::incomplete_jobs`]) gates on every
//!   queued span having its done span — on a zero-drop trace an
//!   incomplete chain is a lost job, not ring pressure.
//!
//! `repro status` renders all of the above — coordinator counters,
//! lifecycle tails, lane gauges, fleet sweep, trace health, SLO burn
//! state — in one snapshot, with `--check` exit gates for CI.
//!
//! Threads + channels stand in for an async runtime (tokio is not in the
//! vendored dependency closure; the control flow is identical).

pub mod batcher;
pub mod drift;
pub mod handle;
pub mod ingest;
pub mod metrics;
pub mod router;
pub mod service;

pub use batcher::{
    plan_batches, BatchPolicy, BatchRule, BucketSeconds, PendingJob, PlannedBatch,
    SplitPoints, DEFAULT_FLUSH_FLOOR, DEFAULT_MIN_SPLIT_MARGIN,
};
pub use drift::{DriftConfig, DriftMonitor, DEFAULT_LINK_BETA};
pub use handle::{TableHandle, TableView};
pub use ingest::{IngestClosed, IngestLanes, IngestStats, IngestStatsSnapshot, IngestWait};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{nearest_bucket, PlanRouter, RoutedPlan, SelectionRules};
pub use service::{AllReduceService, JobResult, JobStages, ObserveMode, ServiceConfig};
