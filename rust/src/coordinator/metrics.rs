//! Service metrics: lock-free counters + snapshot.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub floats_reduced: AtomicU64,
    pub reduce_calls: AtomicU64,
    /// Nanoseconds spent executing plans.
    pub busy_nanos: AtomicU64,
    /// Times the leader had to fall back to the scalar reducer because
    /// the configured reducer spec failed to build (0 or 1 per leader).
    pub reducer_fallbacks: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub batches_flushed: u64,
    pub floats_reduced: u64,
    pub reduce_calls: u64,
    pub busy_secs: f64,
    pub reducer_fallbacks: u64,
}

impl Metrics {
    pub fn add(&self, field: &AtomicU64, v: u64) {
        field.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            batches_flushed: self.batches_flushed.load(Ordering::Relaxed),
            floats_reduced: self.floats_reduced.load(Ordering::Relaxed),
            reduce_calls: self.reduce_calls.load(Ordering::Relaxed),
            busy_secs: self.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            reducer_fallbacks: self.reducer_fallbacks.load(Ordering::Relaxed),
        }
    }
}

impl MetricsSnapshot {
    /// Average fused batch size in jobs (batching effectiveness).
    pub fn jobs_per_batch(&self) -> f64 {
        if self.batches_flushed == 0 {
            0.0
        } else {
            self.jobs_completed as f64 / self.batches_flushed as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.jobs_submitted, 3);
        m.add(&m.jobs_completed, 3);
        m.add(&m.batches_flushed, 1);
        m.add(&m.busy_nanos, 2_000_000_000);
        let s = m.snapshot();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_per_batch(), 3.0);
        assert!((s.busy_secs - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.jobs_per_batch(), 0.0);
    }
}
