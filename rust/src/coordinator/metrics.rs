//! Service metrics: lock-free counters + snapshot.

use std::sync::atomic::{AtomicU64, Ordering};

use super::batcher::BatchRule;

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub floats_reduced: AtomicU64,
    pub reduce_calls: AtomicU64,
    /// Nanoseconds spent executing plans.
    pub busy_nanos: AtomicU64,
    /// Times the leader had to fall back to the scalar reducer because
    /// the configured reducer spec failed to build (0 or 1 per leader).
    pub reducer_fallbacks: AtomicU64,
    /// Batches closed by each [`BatchRule`] — the selection-aware
    /// batcher's split/fuse decisions, countable per rule family.
    pub batches_fused_to_cap: AtomicU64,
    pub batches_split_at_bucket: AtomicU64,
    pub batches_oversized: AtomicU64,
    pub batches_drained: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub batches_flushed: u64,
    pub floats_reduced: u64,
    pub reduce_calls: u64,
    pub busy_secs: f64,
    pub reducer_fallbacks: u64,
    pub batches_fused_to_cap: u64,
    pub batches_split_at_bucket: u64,
    pub batches_oversized: u64,
    pub batches_drained: u64,
}

impl Metrics {
    pub fn add(&self, field: &AtomicU64, v: u64) {
        field.fetch_add(v, Ordering::Relaxed);
    }

    /// Count one emitted batch under the rule that closed it.
    pub fn record_rule(&self, rule: &BatchRule) {
        let field = match rule {
            BatchRule::FusedToCap => &self.batches_fused_to_cap,
            BatchRule::SplitAtBucket { .. } => &self.batches_split_at_bucket,
            BatchRule::Oversized => &self.batches_oversized,
            BatchRule::Drained => &self.batches_drained,
        };
        self.add(field, 1);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            batches_flushed: self.batches_flushed.load(Ordering::Relaxed),
            floats_reduced: self.floats_reduced.load(Ordering::Relaxed),
            reduce_calls: self.reduce_calls.load(Ordering::Relaxed),
            busy_secs: self.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            reducer_fallbacks: self.reducer_fallbacks.load(Ordering::Relaxed),
            batches_fused_to_cap: self.batches_fused_to_cap.load(Ordering::Relaxed),
            batches_split_at_bucket: self.batches_split_at_bucket.load(Ordering::Relaxed),
            batches_oversized: self.batches_oversized.load(Ordering::Relaxed),
            batches_drained: self.batches_drained.load(Ordering::Relaxed),
        }
    }
}

impl MetricsSnapshot {
    /// Average fused batch size in jobs (batching effectiveness).
    pub fn jobs_per_batch(&self) -> f64 {
        if self.batches_flushed == 0 {
            0.0
        } else {
            self.jobs_completed as f64 / self.batches_flushed as f64
        }
    }

    /// Per-rule batch counts as `(stable key, count)` rows, in the order
    /// the rules are documented — one loop serves the CLI report and the
    /// bench JSON.
    pub fn rule_counts(&self) -> [(&'static str, u64); 4] {
        [
            (BatchRule::FusedToCap.name(), self.batches_fused_to_cap),
            (
                BatchRule::SplitAtBucket { bucket: 0, margin: 0.0 }.name(),
                self.batches_split_at_bucket,
            ),
            (BatchRule::Oversized.name(), self.batches_oversized),
            (BatchRule::Drained.name(), self.batches_drained),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.jobs_submitted, 3);
        m.add(&m.jobs_completed, 3);
        m.add(&m.batches_flushed, 1);
        m.add(&m.busy_nanos, 2_000_000_000);
        let s = m.snapshot();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_per_batch(), 3.0);
        assert!((s.busy_secs - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.jobs_per_batch(), 0.0);
    }

    #[test]
    fn every_rule_lands_in_its_own_counter() {
        let m = Metrics::default();
        m.record_rule(&BatchRule::FusedToCap);
        m.record_rule(&BatchRule::FusedToCap);
        m.record_rule(&BatchRule::SplitAtBucket { bucket: 13, margin: 2.0 });
        m.record_rule(&BatchRule::Oversized);
        m.record_rule(&BatchRule::Drained);
        let s = m.snapshot();
        assert_eq!(s.batches_fused_to_cap, 2);
        assert_eq!(s.batches_split_at_bucket, 1);
        assert_eq!(s.batches_oversized, 1);
        assert_eq!(s.batches_drained, 1);
        assert_eq!(
            s.rule_counts(),
            [
                ("fused-to-cap", 2),
                ("split-at-bucket", 1),
                ("oversized", 1),
                ("drained", 1)
            ]
        );
    }
}
