//! Service metrics: lock-free counters + snapshot, including the
//! service-wide per-batch latency histogram telemetry builds on.
//!
//! **Invariant:** every flushed batch is counted under exactly one
//! [`BatchRule`], so the per-rule counters sum to `batches_flushed`.
//! [`Metrics::record_batch`] is the one entry point that maintains it
//! (bumping `batches_flushed` *before* the rule counter, with
//! Release/Acquire pairing against the snapshot's loads, so a concurrent
//! snapshot can momentarily read `rule sum < batches_flushed`, never
//! more); [`Metrics::snapshot`] debug-asserts the ≤ direction and
//! [`MetricsSnapshot::rules_consistent`] checks exact equality for
//! quiescent readers (tests, end-of-run reports).

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::telemetry::{HistSnapshot, LatencyHist};
use crate::trace::{Term, TermAttribution};

use super::batcher::BatchRule;
use super::ingest::{IngestStats, IngestStatsSnapshot};

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub floats_reduced: AtomicU64,
    pub reduce_calls: AtomicU64,
    /// Nanoseconds spent executing plans.
    pub busy_nanos: AtomicU64,
    /// Times the leader had to fall back to the scalar reducer because
    /// the configured reducer spec failed to build (0 or 1 per leader).
    pub reducer_fallbacks: AtomicU64,
    /// Batches closed by each [`BatchRule`] — the selection-aware
    /// batcher's split/fuse decisions, countable per rule family. Summed
    /// they equal `batches_flushed` (see module docs); keep them in sync
    /// through [`Metrics::record_batch`].
    pub batches_fused_to_cap: AtomicU64,
    pub batches_split_at_bucket: AtomicU64,
    pub batches_oversized: AtomicU64,
    pub batches_drained: AtomicU64,
    /// Observed per-batch **execution** latency (wall-clock, or
    /// simulated under `ObserveMode::Sim`) — the service-wide
    /// distribution behind the per-cell telemetry recorder. Execution
    /// only: lane wait, flush-window wait, and batch position are in
    /// [`Self::e2e_latency`] and the per-stage histograms below.
    pub exec_latency: LatencyHist,
    /// True end-to-end job latency: submit → result delivered. This is
    /// what clients actually wait; `exec_latency` under-reports it by
    /// every pre-exec stage (the bug the `serve_latency_p95_s` bench key
    /// inherited until it was re-pointed here).
    pub e2e_latency: LatencyHist,
    /// Per-job lifecycle stages (see `service::JobStages`): time from
    /// submit to the leader's lane drain…
    pub stage_queued: LatencyHist,
    /// …from lane drain to the batch closing (flush window + planning)…
    pub stage_drained: LatencyHist,
    /// …and from batch close to execution start (routing + fusing).
    pub stage_batched: LatencyHist,
    /// SLO burn-rate trips (non-tripped → tripped transitions of the
    /// service's `SloTracker`; 0 when no SLO is configured).
    pub slo_trips: AtomicU64,
    /// Ingest-lane health counters, shared with the service's
    /// `IngestLanes` (depth high-water mark, doorbell sleeps/wakes,
    /// drain-batch sizes). A default-constructed `Metrics` holds an
    /// unwired all-zero instance.
    pub ingest: Arc<IngestStats>,
    /// Drift autopilot: scoring passes the monitor ran.
    pub drift_checks: AtomicU64,
    /// Drift autopilot: successful hot swaps of the selection table.
    pub drift_swaps: AtomicU64,
    /// Drift autopilot: router cache entries evicted across all swaps
    /// (plans whose bucket's winner changed).
    pub drift_evictions: AtomicU64,
    /// Drift autopilot: tripped checks whose recalibration or swap
    /// failed (the active table kept serving).
    pub drift_failures: AtomicU64,
    /// The selection-table epoch currently serving (0 until the first
    /// swap; stays 0 for services without a table handle).
    pub drift_epoch: AtomicU64,
    /// The GenModel term the drift monitor blamed for the *latest* trip
    /// ([`Term::code`]: 1=α 2=wire 3=mem 4=incast 5=unexplained; 0 when
    /// no trip has been attributed yet).
    pub drift_term: AtomicU64,
    /// Cumulative attributed nanoseconds per GenModel term across every
    /// attributed execution span, indexed by [`Term::ALL`] order
    /// (α, wire, mem, incast, unexplained). The unexplained slot
    /// accumulates |unexplained| since the residual is signed. Only fed
    /// when tracing is enabled — all-zero otherwise.
    pub attr_ns: [AtomicU64; 5],
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub batches_flushed: u64,
    pub floats_reduced: u64,
    pub reduce_calls: u64,
    pub busy_secs: f64,
    pub reducer_fallbacks: u64,
    pub batches_fused_to_cap: u64,
    pub batches_split_at_bucket: u64,
    pub batches_oversized: u64,
    pub batches_drained: u64,
    pub exec_latency: HistSnapshot,
    pub e2e_latency: HistSnapshot,
    pub stage_queued: HistSnapshot,
    pub stage_drained: HistSnapshot,
    pub stage_batched: HistSnapshot,
    pub slo_trips: u64,
    pub ingest: IngestStatsSnapshot,
    pub drift_checks: u64,
    pub drift_swaps: u64,
    pub drift_evictions: u64,
    pub drift_failures: u64,
    pub drift_epoch: u64,
    pub drift_term: u64,
    /// Cumulative attributed nanoseconds in [`Term::ALL`] order.
    pub attr_ns: [u64; 5],
}

impl Metrics {
    pub fn add(&self, field: &AtomicU64, v: u64) {
        field.fetch_add(v, Ordering::Relaxed);
    }

    /// Count one flushed batch under the rule that closed it — the single
    /// entry point maintaining the per-rule ↔ `batches_flushed` invariant.
    /// The flush counter is bumped first with `Release`, and the snapshot
    /// reads rule counters with `Acquire` before `batches_flushed`: a
    /// reader that observes a rule increment is therefore guaranteed to
    /// also observe its flush increment, so a concurrent snapshot can see
    /// rule sum < `batches_flushed` mid-record, never more.
    pub fn record_batch(&self, rule: &BatchRule) {
        self.batches_flushed.fetch_add(1, Ordering::Release);
        self.rule_counter(rule).fetch_add(1, Ordering::Release);
    }

    /// Fold one execution span's term attribution into the cumulative
    /// per-term gauges (called by the leader only when tracing is on).
    /// Each term contributes its non-negative seconds; the signed
    /// unexplained residual contributes its magnitude.
    pub fn record_attribution(&self, attr: &TermAttribution) {
        for (slot, term) in self.attr_ns.iter().zip(Term::ALL) {
            let secs = match term {
                Term::Unexplained => attr.term(term).abs(),
                _ => attr.term(term).max(0.0),
            };
            slot.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        }
    }

    /// Record which GenModel term the drift monitor blamed for its
    /// latest trip.
    pub fn set_drift_term(&self, term: Term) {
        self.drift_term.store(term.code(), Ordering::Relaxed);
    }

    /// The per-rule counter. Callers outside this module should go
    /// through [`Self::record_batch`]; bumping a rule counter without its
    /// flush breaks the invariant the snapshot debug-asserts.
    fn rule_counter(&self, rule: &BatchRule) -> &AtomicU64 {
        match rule {
            BatchRule::FusedToCap => &self.batches_fused_to_cap,
            BatchRule::SplitAtBucket { .. } => &self.batches_split_at_bucket,
            BatchRule::Oversized => &self.batches_oversized,
            BatchRule::Drained => &self.batches_drained,
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        // Rule counters are read first with Acquire (pairing with
        // record_batch's Release stores), then batches_flushed: any rule
        // increment this snapshot observes carries visibility of its
        // preceding flush increment, so rule sum ≤ batches_flushed holds
        // even against a mid-record writer.
        let batches_fused_to_cap = self.batches_fused_to_cap.load(Ordering::Acquire);
        let batches_split_at_bucket = self.batches_split_at_bucket.load(Ordering::Acquire);
        let batches_oversized = self.batches_oversized.load(Ordering::Acquire);
        let batches_drained = self.batches_drained.load(Ordering::Acquire);
        let snap = MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            batches_flushed: self.batches_flushed.load(Ordering::Relaxed),
            floats_reduced: self.floats_reduced.load(Ordering::Relaxed),
            reduce_calls: self.reduce_calls.load(Ordering::Relaxed),
            busy_secs: self.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            reducer_fallbacks: self.reducer_fallbacks.load(Ordering::Relaxed),
            batches_fused_to_cap,
            batches_split_at_bucket,
            batches_oversized,
            batches_drained,
            exec_latency: self.exec_latency.snapshot(),
            e2e_latency: self.e2e_latency.snapshot(),
            stage_queued: self.stage_queued.snapshot(),
            stage_drained: self.stage_drained.snapshot(),
            stage_batched: self.stage_batched.snapshot(),
            slo_trips: self.slo_trips.load(Ordering::Relaxed),
            ingest: self.ingest.snapshot(),
            drift_checks: self.drift_checks.load(Ordering::Relaxed),
            drift_swaps: self.drift_swaps.load(Ordering::Relaxed),
            drift_evictions: self.drift_evictions.load(Ordering::Relaxed),
            drift_failures: self.drift_failures.load(Ordering::Relaxed),
            drift_epoch: self.drift_epoch.load(Ordering::Relaxed),
            drift_term: self.drift_term.load(Ordering::Relaxed),
            attr_ns: {
                let mut ns = [0u64; 5];
                for (dst, src) in ns.iter_mut().zip(&self.attr_ns) {
                    *dst = src.load(Ordering::Relaxed);
                }
                ns
            },
        };
        debug_assert!(
            snap.rule_counts_sum() <= snap.batches_flushed,
            "per-rule batch counters ({}) exceed batches_flushed ({}) — \
             a rule was recorded without its flush (use record_batch)",
            snap.rule_counts_sum(),
            snap.batches_flushed,
        );
        snap
    }
}

impl MetricsSnapshot {
    /// Average fused batch size in jobs (batching effectiveness).
    pub fn jobs_per_batch(&self) -> f64 {
        if self.batches_flushed == 0 {
            0.0
        } else {
            self.jobs_completed as f64 / self.batches_flushed as f64
        }
    }

    /// Per-rule batch counts as `(stable key, count)` rows, in the order
    /// the rules are documented — one loop serves the CLI report and the
    /// bench JSON.
    pub fn rule_counts(&self) -> [(&'static str, u64); 4] {
        [
            (BatchRule::FusedToCap.name(), self.batches_fused_to_cap),
            (
                BatchRule::SplitAtBucket { bucket: 0, margin: 0.0 }.name(),
                self.batches_split_at_bucket,
            ),
            (BatchRule::Oversized.name(), self.batches_oversized),
            (BatchRule::Drained.name(), self.batches_drained),
        ]
    }

    /// Sum of the per-rule counters — equals [`Self::batches_flushed`]
    /// in any quiescent snapshot (the invariant in the module docs).
    pub fn rule_counts_sum(&self) -> u64 {
        self.rule_counts().iter().map(|(_, c)| c).sum()
    }

    /// Whether the per-rule ↔ flushed invariant holds exactly — true for
    /// every snapshot taken while no batch is mid-record.
    pub fn rules_consistent(&self) -> bool {
        self.rule_counts_sum() == self.batches_flushed
    }

    /// Render the snapshot in the Prometheus text exposition format
    /// (`# TYPE` headers, `_total` counters, labelled gauges) for
    /// `repro serve --metrics-text`. Latency quantiles are emitted only
    /// when the histogram has observations — an idle service exports the
    /// count at 0 rather than a fabricated 0-second p99.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        counter(
            "allreduce_jobs_submitted_total",
            "Jobs accepted by the coordinator queue.",
            self.jobs_submitted,
        );
        counter(
            "allreduce_jobs_completed_total",
            "Jobs whose batch finished executing.",
            self.jobs_completed,
        );
        counter(
            "allreduce_batches_flushed_total",
            "Batches the size-bucketing batcher closed.",
            self.batches_flushed,
        );
        counter(
            "allreduce_floats_reduced_total",
            "Elements reduced across all batches.",
            self.floats_reduced,
        );
        counter(
            "allreduce_reduce_calls_total",
            "Fan-in-k reducer invocations.",
            self.reduce_calls,
        );
        counter(
            "allreduce_reducer_fallbacks_total",
            "Leaders that fell back to the scalar reducer.",
            self.reducer_fallbacks,
        );
        counter(
            "allreduce_drift_checks_total",
            "Drift autopilot scoring passes.",
            self.drift_checks,
        );
        counter(
            "allreduce_drift_swaps_total",
            "Selection-table hot swaps.",
            self.drift_swaps,
        );
        counter(
            "allreduce_drift_evictions_total",
            "Router cache entries evicted by swaps.",
            self.drift_evictions,
        );
        counter(
            "allreduce_drift_failures_total",
            "Tripped checks whose recalibration failed.",
            self.drift_failures,
        );

        let _ = writeln!(
            out,
            "# HELP allreduce_busy_seconds_total Wall-clock seconds spent executing plans."
        );
        let _ = writeln!(out, "# TYPE allreduce_busy_seconds_total counter");
        let _ = writeln!(out, "allreduce_busy_seconds_total {}", self.busy_secs);

        let _ = writeln!(
            out,
            "# HELP allreduce_batches_by_rule_total Batches closed per batcher rule."
        );
        let _ = writeln!(out, "# TYPE allreduce_batches_by_rule_total counter");
        for (rule, count) in self.rule_counts() {
            let _ = writeln!(out, "allreduce_batches_by_rule_total{{rule=\"{rule}\"}} {count}");
        }

        // Latency summaries: the exec family keeps its original name
        // (dashboards track it as a series); e2e is what clients wait.
        let mut summary = |name: &str, help: &str, hist: &HistSnapshot| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} summary");
            for (q, v) in [("0.5", hist.p50()), ("0.95", hist.p95()), ("0.99", hist.p99())] {
                if let Some(v) = v {
                    let _ = writeln!(out, "{name}{{quantile=\"{q}\"}} {v}");
                }
            }
            let _ = writeln!(out, "{name}_count {}", hist.count());
        };
        summary(
            "allreduce_latency_seconds",
            "Per-batch execution latency quantiles (exec stage only).",
            &self.exec_latency,
        );
        summary(
            "allreduce_e2e_latency_seconds",
            "Per-job end-to-end latency quantiles (submit to result).",
            &self.e2e_latency,
        );

        // Per-stage lifecycle quantiles under one labelled family.
        let _ = writeln!(
            out,
            "# HELP allreduce_stage_seconds Per-job lifecycle stage duration quantiles \
             (queued = submit to lane drain, drained = drain to batch close, \
             batched = batch close to exec start)."
        );
        let _ = writeln!(out, "# TYPE allreduce_stage_seconds summary");
        for (stage, hist) in [
            ("queued", &self.stage_queued),
            ("drained", &self.stage_drained),
            ("batched", &self.stage_batched),
        ] {
            for (q, v) in [("0.5", hist.p50()), ("0.95", hist.p95()), ("0.99", hist.p99())] {
                if let Some(v) = v {
                    let _ = writeln!(
                        out,
                        "allreduce_stage_seconds{{stage=\"{stage}\",quantile=\"{q}\"}} {v}"
                    );
                }
            }
            let _ = writeln!(
                out,
                "allreduce_stage_seconds_count{{stage=\"{stage}\"}} {}",
                hist.count()
            );
        }

        // SLO watchdog + ingest-lane health.
        let _ = writeln!(
            out,
            "# HELP allreduce_slo_trips_total SLO burn-rate trips (sustained e2e-latency burns)."
        );
        let _ = writeln!(out, "# TYPE allreduce_slo_trips_total counter");
        let _ = writeln!(out, "allreduce_slo_trips_total {}", self.slo_trips);

        let _ = writeln!(
            out,
            "# HELP allreduce_ingest_depth_hwm Deepest ingest-lane backlog ever observed."
        );
        let _ = writeln!(out, "# TYPE allreduce_ingest_depth_hwm gauge");
        let _ = writeln!(out, "allreduce_ingest_depth_hwm {}", self.ingest.depth_hwm);

        let _ = writeln!(
            out,
            "# HELP allreduce_ingest_sleeps_total Times the leader parked on the ingest doorbell."
        );
        let _ = writeln!(out, "# TYPE allreduce_ingest_sleeps_total counter");
        let _ = writeln!(out, "allreduce_ingest_sleeps_total {}", self.ingest.sleeps);

        let _ = writeln!(
            out,
            "# HELP allreduce_ingest_wakes_total Times a producer rang the doorbell."
        );
        let _ = writeln!(out, "# TYPE allreduce_ingest_wakes_total counter");
        let _ = writeln!(out, "allreduce_ingest_wakes_total {}", self.ingest.wakes);

        let _ = writeln!(
            out,
            "# HELP allreduce_ingest_drain_jobs Jobs collected per non-empty drain sweep."
        );
        let _ = writeln!(out, "# TYPE allreduce_ingest_drain_jobs summary");
        for q in ["0.5", "0.95", "0.99"] {
            let quant: f64 = q.parse().unwrap();
            if let Some(v) = self.ingest.drain_quantile(quant) {
                let _ = writeln!(out, "allreduce_ingest_drain_jobs{{quantile=\"{q}\"}} {v}");
            }
        }
        let _ = writeln!(out, "allreduce_ingest_drain_jobs_count {}", self.ingest.drains);

        let _ = writeln!(
            out,
            "# HELP allreduce_drift_epoch Selection-table epoch currently serving."
        );
        let _ = writeln!(out, "# TYPE allreduce_drift_epoch gauge");
        let _ = writeln!(out, "allreduce_drift_epoch {}", self.drift_epoch);

        let _ = writeln!(
            out,
            "# HELP allreduce_drift_term GenModel term blamed for the latest drift trip \
             (0=none 1=alpha 2=wire 3=mem 4=incast 5=unexplained)."
        );
        let _ = writeln!(out, "# TYPE allreduce_drift_term gauge");
        let _ = writeln!(out, "allreduce_drift_term {}", self.drift_term);

        let _ = writeln!(
            out,
            "# HELP allreduce_attr_seconds_total Attributed execution seconds per GenModel term."
        );
        let _ = writeln!(out, "# TYPE allreduce_attr_seconds_total counter");
        for (term, ns) in Term::ALL.iter().zip(self.attr_ns) {
            let _ = writeln!(
                out,
                "allreduce_attr_seconds_total{{term=\"{}\"}} {}",
                term.name(),
                ns as f64 * 1e-9
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.jobs_submitted, 3);
        m.add(&m.jobs_completed, 3);
        m.record_batch(&BatchRule::Drained);
        m.add(&m.busy_nanos, 2_000_000_000);
        let s = m.snapshot();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_per_batch(), 3.0);
        assert!((s.busy_secs - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.jobs_per_batch(), 0.0);
        assert!(s.rules_consistent());
        assert_eq!(s.exec_latency.count(), 0);
        assert_eq!(s.e2e_latency.count(), 0);
        assert_eq!(s.slo_trips, 0);
        assert_eq!(s.ingest.depth_hwm, 0);
    }

    #[test]
    fn every_rule_lands_in_its_own_counter() {
        let m = Metrics::default();
        m.record_batch(&BatchRule::FusedToCap);
        m.record_batch(&BatchRule::FusedToCap);
        m.record_batch(&BatchRule::SplitAtBucket { bucket: 13, margin: 2.0 });
        m.record_batch(&BatchRule::Oversized);
        m.record_batch(&BatchRule::Drained);
        let s = m.snapshot();
        assert_eq!(s.batches_fused_to_cap, 2);
        assert_eq!(s.batches_split_at_bucket, 1);
        assert_eq!(s.batches_oversized, 1);
        assert_eq!(s.batches_drained, 1);
        assert_eq!(
            s.rule_counts(),
            [
                ("fused-to-cap", 2),
                ("split-at-bucket", 1),
                ("oversized", 1),
                ("drained", 1)
            ]
        );
    }

    #[test]
    fn record_batch_keeps_rules_and_flushes_in_lockstep() {
        let m = Metrics::default();
        m.record_batch(&BatchRule::FusedToCap);
        m.record_batch(&BatchRule::Oversized);
        m.record_batch(&BatchRule::Drained);
        let s = m.snapshot();
        assert_eq!(s.batches_flushed, 3);
        assert_eq!(s.rule_counts_sum(), 3);
        assert!(s.rules_consistent());
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "record_batch")]
    fn orphan_rule_count_trips_the_invariant() {
        let m = Metrics::default();
        // A rule bump without its flush — the misuse record_batch exists
        // to prevent.
        m.rule_counter(&BatchRule::Drained).fetch_add(1, Ordering::Relaxed);
        let _ = m.snapshot();
    }

    #[test]
    fn latency_histograms_feed_the_snapshot() {
        let m = Metrics::default();
        m.exec_latency.record_secs(0.001);
        m.exec_latency.record_secs(0.001);
        m.exec_latency.record_secs(0.1);
        let s = m.snapshot();
        assert_eq!(s.exec_latency.count(), 3);
        assert!(s.exec_latency.p50().unwrap() < s.exec_latency.p99().unwrap());
        // e2e and stage hists are independent series: exec records alone
        // must not leak into them.
        assert_eq!(s.e2e_latency.count(), 0);
        m.e2e_latency.record_secs(0.2);
        m.stage_queued.record_secs(0.05);
        m.stage_drained.record_secs(0.01);
        m.stage_batched.record_secs(0.001);
        let s = m.snapshot();
        assert_eq!(s.e2e_latency.count(), 1);
        assert_eq!(s.stage_queued.count(), 1);
        assert_eq!(s.stage_drained.count(), 1);
        assert_eq!(s.stage_batched.count(), 1);
    }

    #[test]
    fn attribution_accumulates_per_term() {
        let m = Metrics::default();
        let attr = TermAttribution {
            alpha_s: 0.5,
            wire_s: 0.25,
            incast_s: 1.5,
            mem_s: 0.125,
            unexplained_s: -0.375,
        };
        m.record_attribution(&attr);
        m.record_attribution(&attr);
        let s = m.snapshot();
        // Term::ALL order: alpha, wire, mem, incast, unexplained; the
        // signed residual lands as its magnitude.
        assert_eq!(s.attr_ns, [1_000_000_000, 500_000_000, 250_000_000, 3_000_000_000, 750_000_000]);
    }

    #[test]
    fn prometheus_text_has_counters_quantiles_and_terms() {
        let m = Metrics::default();
        m.add(&m.jobs_submitted, 7);
        m.record_batch(&BatchRule::Drained);
        m.exec_latency.record_secs(0.002);
        m.e2e_latency.record_secs(0.004);
        m.stage_queued.record_secs(0.001);
        m.add(&m.slo_trips, 2);
        m.set_drift_term(Term::Incast);
        m.record_attribution(&TermAttribution {
            incast_s: 1.0,
            ..TermAttribution::default()
        });
        let text = m.snapshot().render_prometheus();
        assert!(text.contains("allreduce_jobs_submitted_total 7"));
        assert!(text.contains("allreduce_batches_by_rule_total{rule=\"drained\"} 1"));
        assert!(text.contains("allreduce_latency_seconds{quantile=\"0.95\"}"));
        assert!(text.contains("allreduce_latency_seconds_count 1"));
        assert!(text.contains("allreduce_e2e_latency_seconds{quantile=\"0.95\"}"));
        assert!(text.contains("allreduce_e2e_latency_seconds_count 1"));
        assert!(text.contains("allreduce_stage_seconds{stage=\"queued\",quantile=\"0.5\"}"));
        assert!(text.contains("allreduce_stage_seconds_count{stage=\"queued\"} 1"));
        assert!(text.contains("allreduce_stage_seconds_count{stage=\"drained\"} 0"));
        assert!(text.contains("allreduce_slo_trips_total 2"));
        assert!(text.contains("allreduce_ingest_depth_hwm 0"));
        assert!(text.contains("allreduce_ingest_sleeps_total 0"));
        assert!(text.contains("allreduce_ingest_wakes_total 0"));
        assert!(text.contains("allreduce_ingest_drain_jobs_count 0"));
        assert!(text.contains("allreduce_drift_term 4"));
        assert!(text.contains("allreduce_attr_seconds_total{term=\"incast\"} 1"));
        // Every exposition family declares its TYPE.
        assert!(text.contains("# TYPE allreduce_latency_seconds summary"));
        assert!(text.contains("# TYPE allreduce_e2e_latency_seconds summary"));
        assert!(text.contains("# TYPE allreduce_stage_seconds summary"));
        assert!(text.contains("# TYPE allreduce_slo_trips_total counter"));
        assert!(text.contains("# TYPE allreduce_ingest_depth_hwm gauge"));
    }

    #[test]
    fn idle_prometheus_text_omits_fabricated_quantiles() {
        let text = Metrics::default().snapshot().render_prometheus();
        assert!(!text.contains("allreduce_latency_seconds{quantile"));
        assert!(text.contains("allreduce_latency_seconds_count 0"));
        assert!(!text.contains("allreduce_e2e_latency_seconds{quantile"));
        assert!(!text.contains("allreduce_stage_seconds{stage"));
        assert!(!text.contains("allreduce_ingest_drain_jobs{quantile"));
        assert!(text.contains("allreduce_e2e_latency_seconds_count 0"));
        assert!(text.contains("allreduce_stage_seconds_count{stage=\"batched\"} 0"));
    }
}
