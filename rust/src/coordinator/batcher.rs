//! Gradient bucketing: decide which pending jobs fuse into one round.
//!
//! Pure logic (no threads) so it is directly testable: jobs are taken in
//! FIFO order; a batch closes when adding the next job would exceed
//! `bucket_floats`, or when the queue is drained. A single oversized job
//! always forms its own batch (it cannot be split across rounds — the
//! plan's block partition already parallelizes it).

/// One pending job's metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PendingJob {
    pub id: u64,
    /// Per-worker tensor length in floats.
    pub floats: usize,
}

/// Batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Target fused payload size (floats). Mirrors DDP's bucket_cap.
    pub bucket_floats: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // 25 MB of f32 — the ubiquitous DDP default bucket.
        BatchPolicy {
            bucket_floats: 25 * (1 << 20) / 4,
        }
    }
}

/// Split the FIFO queue into batches under the policy.
pub fn plan_batches(queue: &[PendingJob], policy: &BatchPolicy) -> Vec<Vec<PendingJob>> {
    let mut out = Vec::new();
    let mut cur: Vec<PendingJob> = Vec::new();
    let mut cur_floats = 0usize;
    for &j in queue {
        if !cur.is_empty() && cur_floats + j.floats > policy.bucket_floats {
            out.push(std::mem::take(&mut cur));
            cur_floats = 0;
        }
        cur_floats += j.floats;
        cur.push(j);
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Offsets of each job inside the fused buffer of a batch.
pub fn fuse_offsets(batch: &[PendingJob]) -> Vec<(u64, usize, usize)> {
    let mut out = Vec::with_capacity(batch.len());
    let mut off = 0usize;
    for j in batch {
        out.push((j.id, off, j.floats));
        off += j.floats;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs(sizes: &[usize]) -> Vec<PendingJob> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| PendingJob {
                id: i as u64,
                floats: s,
            })
            .collect()
    }

    #[test]
    fn small_jobs_fuse() {
        let q = jobs(&[100, 200, 300]);
        let batches = plan_batches(&q, &BatchPolicy { bucket_floats: 1000 });
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 3);
    }

    #[test]
    fn bucket_boundary_splits() {
        let q = jobs(&[600, 600, 600]);
        let batches = plan_batches(&q, &BatchPolicy { bucket_floats: 1000 });
        assert_eq!(batches.len(), 3); // 600+600 > 1000 each time
    }

    #[test]
    fn oversized_job_alone() {
        let q = jobs(&[5000, 10]);
        let batches = plan_batches(&q, &BatchPolicy { bucket_floats: 1000 });
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0][0].floats, 5000);
    }

    #[test]
    fn fifo_order_preserved() {
        let q = jobs(&[10, 990, 10]);
        let batches = plan_batches(&q, &BatchPolicy { bucket_floats: 1000 });
        let ids: Vec<u64> = batches.concat().iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn offsets_contiguous() {
        let b = jobs(&[5, 7, 3]);
        let offs = fuse_offsets(&b);
        assert_eq!(offs, vec![(0, 0, 5), (1, 5, 7), (2, 12, 3)]);
    }

    #[test]
    fn empty_queue_no_batches() {
        assert!(plan_batches(&[], &BatchPolicy::default()).is_empty());
    }
}
