//! Gradient bucketing: decide which pending jobs fuse into one round —
//! and, when a campaign selection table is in play, *where* a fuse must
//! stop so the fused payload still routes to the algorithm that wins.
//!
//! Pure logic (no threads) so it is directly testable: jobs are taken in
//! FIFO order and every emitted batch reports the [`BatchRule`] that
//! closed it. A batch closes when
//!
//! 1. **`FusedToCap`** — adding the next job would exceed
//!    [`BatchPolicy::bucket_floats`] (DDP's bucket_cap behavior);
//! 2. **`SplitAtBucket`** — adding the next job would drag the fused
//!    size across a router bucket boundary ([`PlanRouter::bucket`])
//!    where the selection table's winner *changes*, and the departed
//!    winner's runner-up margin is at least
//!    [`BatchPolicy::min_split_margin`] (default
//!    [`DEFAULT_MIN_SPLIT_MARGIN`] = 1.25). The margin test is the
//!    fuse-vs-split tiebreak: a 1.05× winner is not worth breaking a
//!    fuse for, a 3× winner is. The departed bucket's margin is a
//!    *lower bound* on the slowdown of fusing through: the fused batch
//!    routes to the far side's (different) winner, which at the departed
//!    size is at best that bucket's runner-up.
//! 3. **`Drained`** — the queue is exhausted (the flush window closed).
//!
//! A single job larger than the cap always forms its own batch
//! (**`Oversized`** — it cannot be split across rounds; the plan's block
//! partition already parallelizes it). Without split points (or with
//! every boundary below the margin threshold) the emitted partition is
//! identical to the original cap-only policy.

use std::collections::BTreeMap;
use std::fmt;
use std::ops::RangeInclusive;
use std::time::Duration;

use crate::campaign::SelectionTable;

use super::router::{nearest_bucket, PlanRouter};

/// Default [`BatchPolicy::min_split_margin`]: a boundary's winner must
/// beat its runner-up by ≥ 25% before the batcher breaks a fuse for it.
pub const DEFAULT_MIN_SPLIT_MARGIN: f64 = 1.25;

/// Default [`BatchPolicy::flush_floor`]: the shortest wait time-aware
/// flushing may impose. A selection table predicting a microsecond-scale
/// round for a small bucket would otherwise shrink the flush window to
/// effectively zero, degenerating the leader into busy-spin flushing of
/// single-job batches — the fuse never forms, which defeats the α-term
/// amortization batching exists for. 100 µs is well under any real
/// AllReduce round while still letting a burst of submissions queue.
pub const DEFAULT_FLUSH_FLOOR: Duration = Duration::from_micros(100);

/// One pending job's metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PendingJob {
    pub id: u64,
    /// Per-worker tensor length in floats.
    pub floats: usize,
}

/// Why a batch was closed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchRule {
    /// Adding the next job would have exceeded the size cap.
    FusedToCap,
    /// Closed early so the fused payload stays in `bucket`, below a
    /// boundary where the selection winner changes with margin ≥ the
    /// policy's `min_split_margin`.
    SplitAtBucket { bucket: u32, margin: f64 },
    /// A single job larger than the cap, alone in its batch.
    Oversized,
    /// The queue drained (flush window closed) with the batch open.
    Drained,
}

impl BatchRule {
    /// Stable metric/report key for the rule family.
    pub fn name(&self) -> &'static str {
        match self {
            BatchRule::FusedToCap => "fused-to-cap",
            BatchRule::SplitAtBucket { .. } => "split-at-bucket",
            BatchRule::Oversized => "oversized",
            BatchRule::Drained => "drained",
        }
    }
}

impl fmt::Display for BatchRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BatchRule::SplitAtBucket { bucket, margin } => {
                write!(f, "split-at-bucket(2^{bucket}, {margin:.2}x)")
            }
            other => f.write_str(other.name()),
        }
    }
}

/// One emitted batch: the fused jobs plus the rule that closed it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedBatch {
    pub jobs: Vec<PendingJob>,
    pub rule: BatchRule,
}

impl PlannedBatch {
    /// Fused payload size of the batch in floats.
    pub fn fused_floats(&self) -> usize {
        self.jobs.iter().map(|j| j.floats).sum()
    }
}

/// The winner-change boundaries of one topology class, distilled from a
/// campaign [`SelectionTable`] into exactly what the batcher consults on
/// the hot path: `(first bucket of the new winner, departed winner's
/// margin)`, bucket-sorted — plus (when built [`from_table`]) the winner
/// of each segment, so a fuse that jumps several boundaries and lands
/// back on the *same* winner (A→B→A) is not split for nothing.
///
/// [`from_table`]: Self::from_table
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SplitPoints {
    points: Vec<(u32, f64)>,
    /// Winner of the segment starting at the same-index boundary in
    /// `points`; empty when built from raw points (no winner info — any
    /// crossed boundary then counts as a winner change).
    winners: Vec<String>,
    /// Winner below the first boundary (`None` for raw points).
    base_winner: Option<String>,
}

impl SplitPoints {
    /// Build from raw `(bucket, margin)` pairs; duplicates keep the
    /// strongest margin so the batcher never under-reports a boundary.
    /// Raw points carry no winner identity, so every crossed boundary is
    /// conservatively treated as a winner change — prefer
    /// [`Self::from_table`] when a table is available.
    pub fn new(mut points: Vec<(u32, f64)>) -> SplitPoints {
        points.sort_by(|a, b| {
            a.0.cmp(&b.0).then(
                b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal),
            )
        });
        points.dedup_by_key(|p| p.0);
        SplitPoints {
            points,
            winners: Vec::new(),
            base_winner: None,
        }
    }

    /// Distill `table`'s winner-change boundaries for `class` (see
    /// [`SelectionTable::boundaries_for`]), keeping each segment's
    /// winner so [`Self::winner_changes`] can see through A→B→A flips.
    pub fn from_table(table: &SelectionTable, class: &str) -> SplitPoints {
        // boundaries_for is bucket-ascending with unique buckets, so the
        // points arrive already in `new`'s canonical order.
        let boundaries = table.boundaries_for(class);
        SplitPoints {
            points: boundaries.iter().map(|b| (b.bucket, b.margin)).collect(),
            winners: boundaries.into_iter().map(|b| b.winner).collect(),
            base_winner: table.lookup(class, 1).map(|c| c.algo.clone()),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// The first boundary a fuse crosses when its payload grows through
    /// `buckets` (a [`PlanRouter::bucket_range`]): the lowest boundary
    /// strictly above the range's start and at-or-below its end. Its
    /// margin belongs to the *departed* segment — the lower bound on
    /// what fusing through costs the jobs already in the batch, and the
    /// only margin the split decision weighs (an interior segment's
    /// margin is irrelevant: neither emitted batch routes its winner).
    pub fn first_crossed(&self, buckets: RangeInclusive<u32>) -> Option<(u32, f64)> {
        self.points
            .iter()
            .copied()
            .find(|&(b, _)| *buckets.start() < b && b <= *buckets.end())
    }

    /// The winning algorithm governing `bucket` — the last boundary at
    /// or below it, else the base winner. `None` without winner info.
    fn winner_at(&self, bucket: u32) -> Option<&str> {
        let mut winner = self.base_winner.as_deref();
        for (i, &(b, _)) in self.points.iter().enumerate() {
            if b > bucket {
                break;
            }
            winner = self.winners.get(i).map(String::as_str);
        }
        winner
    }

    /// Whether the routed winner actually differs between the `from` and
    /// `to` buckets. Raw points (no winner info) always report a change,
    /// matching the conservative pre-winner-aware behavior.
    pub fn winner_changes(&self, from: u32, to: u32) -> bool {
        if self.winners.len() != self.points.len() || self.winners.is_empty() {
            return true;
        }
        self.winner_at(from) != self.winner_at(to)
    }
}

/// Predicted winner seconds per router size bucket, distilled from a
/// selection table ([`SelectionTable::bucket_seconds_for`]) — what
/// time-aware flushing consults: holding a fuse open saves at most one
/// round, so waiting longer than the predicted round time is a net loss.
pub type BucketSeconds = BTreeMap<u32, f64>;

/// Batching configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchPolicy {
    /// Target fused payload size (floats). Mirrors DDP's bucket_cap.
    pub bucket_floats: usize,
    /// Minimum selection margin that justifies breaking a fuse at a
    /// winner-change boundary; weaker winners fuse through. See the
    /// module docs ([`DEFAULT_MIN_SPLIT_MARGIN`] = 1.25).
    pub min_split_margin: f64,
    /// Winner-change boundaries from a selection table. `None` (or an
    /// empty set): cap-only fusing, byte-identical to the pre-selection
    /// policy.
    pub selection: Option<SplitPoints>,
    /// Predicted per-bucket round seconds from a selection table. `None`:
    /// the fixed flush window applies unchanged ([`Self::flush_window`]).
    pub bucket_seconds: Option<BucketSeconds>,
    /// The shortest window time-aware flushing may return
    /// ([`DEFAULT_FLUSH_FLOOR`]): a tiny predicted round time clamps up
    /// to this floor instead of busy-spinning single-job flushes. The
    /// fixed window itself is never inflated — a `flush_after` below the
    /// floor still governs.
    pub flush_floor: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // 25 MB of f32 — the ubiquitous DDP default bucket.
        BatchPolicy {
            bucket_floats: 25 * (1 << 20) / 4,
            min_split_margin: DEFAULT_MIN_SPLIT_MARGIN,
            selection: None,
            bucket_seconds: None,
            flush_floor: DEFAULT_FLUSH_FLOOR,
        }
    }
}

impl BatchPolicy {
    /// Cap-only policy (the historical constructor).
    pub fn with_cap(bucket_floats: usize) -> BatchPolicy {
        BatchPolicy {
            bucket_floats,
            ..BatchPolicy::default()
        }
    }

    /// Consult `table`'s winner-change boundaries for `class` when
    /// deciding where a fuse must stop, and its per-bucket predicted
    /// seconds when deciding how long a flush may wait.
    pub fn with_table(mut self, table: &SelectionTable, class: &str) -> BatchPolicy {
        self.selection = Some(SplitPoints::from_table(table, class));
        self.bucket_seconds = Some(table.bucket_seconds_for(class));
        self
    }

    /// **Time-aware flushing**: the window the leader may hold an open
    /// queue of `queued_floats`, given the configured fixed window
    /// `default`. Holding a fuse saves at most one AllReduce round, so
    /// the wait is capped at the selection table's predicted round time
    /// for the queue's current size bucket (nearest bucket, same clamp
    /// as routing); waiting longer than the round it saves is a strict
    /// loss. A near-zero prediction cannot shrink the window below
    /// [`Self::flush_floor`] — busy-spin flushing of single-job batches
    /// would defeat batching outright — while the fixed window itself is
    /// never extended by the floor. Without bucket seconds (or with a
    /// degenerate prediction) the fixed window is returned unchanged —
    /// byte-identical to the pre-telemetry policy.
    pub fn flush_window(&self, queued_floats: usize, default: Duration) -> Duration {
        let Some(&secs) = self
            .bucket_seconds
            .as_ref()
            .and_then(|m| nearest_bucket(m, PlanRouter::bucket(queued_floats)))
        else {
            return default;
        };
        if !(secs.is_finite() && secs > 0.0) {
            return default;
        }
        default.min(Duration::from_secs_f64(secs).max(self.flush_floor))
    }
}

/// Split the FIFO queue into batches under the policy. Every batch
/// reports the [`BatchRule`] that closed it.
pub fn plan_batches(queue: &[PendingJob], policy: &BatchPolicy) -> Vec<PlannedBatch> {
    let mut out: Vec<PlannedBatch> = Vec::new();
    let mut cur: Vec<PendingJob> = Vec::new();
    let mut cur_floats = 0usize;
    let mut close = |cur: &mut Vec<PendingJob>, cur_floats: &mut usize, trigger: BatchRule| {
        let rule = if cur.len() == 1 && cur[0].floats > policy.bucket_floats {
            BatchRule::Oversized
        } else {
            trigger
        };
        out.push(PlannedBatch {
            jobs: std::mem::take(cur),
            rule,
        });
        *cur_floats = 0;
    };
    for &j in queue {
        if !cur.is_empty() {
            let fused = cur_floats + j.floats;
            if fused > policy.bucket_floats {
                close(&mut cur, &mut cur_floats, BatchRule::FusedToCap);
            } else if let Some(rule) = boundary_split(policy, cur_floats, fused) {
                close(&mut cur, &mut cur_floats, rule);
            }
        }
        cur_floats += j.floats;
        cur.push(j);
    }
    if !cur.is_empty() {
        close(&mut cur, &mut cur_floats, BatchRule::Drained);
    }
    out
}

/// The split rule to apply when fusing the next job would grow the open
/// batch from `cur` to `fused` floats — `Some` only when that growth
/// crosses a winner-change boundary decisive enough to break the fuse
/// AND the winner at the fused size actually differs from the winner at
/// the current size (a jump that flips A→B→A routes the same algorithm
/// either way, so splitting would only buy an extra round). The reported
/// bucket is the one the *emitted* batch lands in.
fn boundary_split(policy: &BatchPolicy, cur: usize, fused: usize) -> Option<BatchRule> {
    let selection = policy.selection.as_ref()?;
    let buckets = PlanRouter::bucket_range(cur, fused);
    let (_, margin) = selection.first_crossed(buckets.clone())?;
    if !selection.winner_changes(*buckets.start(), *buckets.end()) {
        return None;
    }
    if margin >= policy.min_split_margin {
        Some(BatchRule::SplitAtBucket {
            bucket: PlanRouter::bucket(cur),
            margin,
        })
    } else {
        None
    }
}

/// Offsets of each job inside the fused buffer of a batch.
pub fn fuse_offsets(batch: &[PendingJob]) -> Vec<(u64, usize, usize)> {
    let mut out = Vec::with_capacity(batch.len());
    let mut off = 0usize;
    for j in batch {
        out.push((j.id, off, j.floats));
        off += j.floats;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::{table_from_choices, Metric};

    fn jobs(sizes: &[usize]) -> Vec<PendingJob> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| PendingJob {
                id: i as u64,
                floats: s,
            })
            .collect()
    }

    fn ids(batches: &[PlannedBatch]) -> Vec<Vec<u64>> {
        batches
            .iter()
            .map(|b| b.jobs.iter().map(|j| j.id).collect())
            .collect()
    }

    #[test]
    fn small_jobs_fuse() {
        let q = jobs(&[100, 200, 300]);
        let batches = plan_batches(&q, &BatchPolicy::with_cap(1000));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].jobs.len(), 3);
        assert_eq!(batches[0].rule, BatchRule::Drained);
    }

    #[test]
    fn bucket_boundary_splits() {
        let q = jobs(&[600, 600, 600]);
        let batches = plan_batches(&q, &BatchPolicy::with_cap(1000));
        assert_eq!(batches.len(), 3); // 600+600 > 1000 each time
        assert_eq!(batches[0].rule, BatchRule::FusedToCap);
        assert_eq!(batches[1].rule, BatchRule::FusedToCap);
        assert_eq!(batches[2].rule, BatchRule::Drained);
    }

    #[test]
    fn oversized_job_alone() {
        let q = jobs(&[5000, 10]);
        let batches = plan_batches(&q, &BatchPolicy::with_cap(1000));
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].jobs[0].floats, 5000);
        assert_eq!(batches[0].rule, BatchRule::Oversized);
    }

    #[test]
    fn oversized_at_queue_tail_still_reports_oversized() {
        let q = jobs(&[10, 5000]);
        let batches = plan_batches(&q, &BatchPolicy::with_cap(1000));
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[1].rule, BatchRule::Oversized);
    }

    #[test]
    fn fifo_order_preserved() {
        let q = jobs(&[10, 990, 10]);
        let batches = plan_batches(&q, &BatchPolicy::with_cap(1000));
        let flat: Vec<u64> = ids(&batches).concat();
        assert_eq!(flat, vec![0, 1, 2]);
    }

    #[test]
    fn offsets_contiguous() {
        let b = jobs(&[5, 7, 3]);
        let offs = fuse_offsets(&b);
        assert_eq!(offs, vec![(0, 0, 5), (1, 5, 7), (2, 12, 3)]);
    }

    #[test]
    fn empty_queue_no_batches() {
        assert!(plan_batches(&[], &BatchPolicy::default()).is_empty());
    }

    // ---- selection-aware splitting ------------------------------------

    /// Boundary at bucket 14 (payloads > 2^13 floats), departed-side
    /// margin as given.
    fn policy_with_boundary(margin: f64) -> BatchPolicy {
        BatchPolicy {
            selection: Some(SplitPoints::new(vec![(14, margin)])),
            ..BatchPolicy::with_cap(1 << 22)
        }
    }

    #[test]
    fn decisive_boundary_splits_the_fuse() {
        // 3000 + 3000 stays below 2^13; adding 20000 would cross the
        // bucket-14 boundary, and a 3.0x winner is worth the split.
        let q = jobs(&[3000, 3000, 20_000]);
        let batches = plan_batches(&q, &policy_with_boundary(3.0));
        assert_eq!(ids(&batches), vec![vec![0, 1], vec![2]]);
        assert_eq!(
            batches[0].rule,
            BatchRule::SplitAtBucket {
                bucket: PlanRouter::bucket(6000),
                margin: 3.0
            }
        );
        // The emitted batch's fused size lands inside the claimed bucket.
        assert_eq!(PlanRouter::bucket(batches[0].fused_floats()), 13);
        assert_eq!(batches[1].rule, BatchRule::Drained);
    }

    #[test]
    fn weak_boundary_fuses_through() {
        // A 1.05x winner is not worth breaking a fuse: the partition is
        // identical to the cap-only policy.
        let q = jobs(&[3000, 3000, 20_000]);
        let with = plan_batches(&q, &policy_with_boundary(1.05));
        let without = plan_batches(&q, &BatchPolicy::with_cap(1 << 22));
        assert_eq!(ids(&with), ids(&without));
        assert_eq!(ids(&with), vec![vec![0, 1, 2]]);
    }

    #[test]
    fn cap_takes_precedence_over_boundary() {
        // Both the cap and a decisive boundary fire on the same job: the
        // cap rule reports (the partition matches the cap-only policy).
        let q = jobs(&[3000, 3000, 20_000]);
        let policy = BatchPolicy {
            selection: Some(SplitPoints::new(vec![(14, 3.0)])),
            ..BatchPolicy::with_cap(7000)
        };
        let batches = plan_batches(&q, &policy);
        assert_eq!(ids(&batches), vec![vec![0, 1], vec![2]]);
        assert_eq!(batches[0].rule, BatchRule::FusedToCap);
        assert_eq!(batches[1].rule, BatchRule::Oversized);
    }

    #[test]
    fn multi_bucket_jump_weighs_the_departed_boundary_margin() {
        // One large job drags the fuse across two boundaries at once; the
        // decision (and the reported margin) is the FIRST crossed
        // boundary's — the departed segment's own winner/runner-up ratio.
        // An interior segment's stronger margin is irrelevant: neither
        // emitted batch routes that segment's winner.
        let q = jobs(&[1000, 200_000]);
        let policy = BatchPolicy {
            selection: Some(SplitPoints::new(vec![(12, 1.5), (16, 2.5)])),
            ..BatchPolicy::with_cap(1 << 22)
        };
        let batches = plan_batches(&q, &policy);
        assert_eq!(ids(&batches), vec![vec![0], vec![1]]);
        assert_eq!(
            batches[0].rule,
            BatchRule::SplitAtBucket {
                bucket: PlanRouter::bucket(1000),
                margin: 1.5
            }
        );
        // A weak departed margin holds the fuse even when an interior
        // boundary is decisive — the 5.0x belongs to a winner neither
        // batch would route.
        let policy = BatchPolicy {
            selection: Some(SplitPoints::new(vec![(12, 1.1), (16, 5.0)])),
            ..BatchPolicy::with_cap(1 << 22)
        };
        let batches = plan_batches(&q, &policy);
        assert_eq!(ids(&batches), vec![vec![0, 1]]);
    }

    #[test]
    fn winner_flip_back_does_not_split() {
        // ring → rhd → ring across the size axis: a jump that crosses
        // BOTH boundaries routes ring on either side, so splitting would
        // only buy an extra round — the fuse must hold. A jump landing
        // inside rhd's reign still splits.
        let table = table_from_choices(
            Metric::Model,
            &[
                ("x", 10, "ring", 1.0, 3.0),
                ("x", 14, "rhd", 1.0, 3.0),
                ("x", 17, "ring", 1.0, 2.0),
            ],
        );
        let policy = BatchPolicy {
            selection: Some(SplitPoints::from_table(&table, "x")),
            ..BatchPolicy::with_cap(1 << 22)
        };
        // 3000 (bucket 12) + 200_000 → 203_000 (bucket 18): ring → ring.
        let batches = plan_batches(&jobs(&[3000, 200_000]), &policy);
        assert_eq!(ids(&batches), vec![vec![0, 1]], "A→B→A jump must fuse");
        // 3000 + 60_000 → 63_000 (bucket 16): ring → rhd, split.
        let batches = plan_batches(&jobs(&[3000, 60_000]), &policy);
        assert_eq!(ids(&batches), vec![vec![0], vec![1]]);
        assert_eq!(
            batches[0].rule,
            BatchRule::SplitAtBucket { bucket: 12, margin: 3.0 }
        );
    }

    #[test]
    fn split_points_distill_from_a_selection_table() {
        let table = table_from_choices(
            Metric::Model,
            &[
                ("single:8", 10, "ring", 1.0, 3.0),
                ("single:8", 14, "rhd", 1.0, 2.0),
            ],
        );
        let pts = SplitPoints::from_table(&table, "single:8");
        assert_eq!(pts.len(), 1);
        // The boundary sits where rhd takes over; its margin is the
        // departed (ring) cell's runner-up margin.
        assert_eq!(pts.first_crossed(13..=14), Some((14, 3.0)));
        assert_eq!(pts.first_crossed(14..=20), None, "already across");
        assert!(SplitPoints::from_table(&table, "absent").is_empty());
    }

    #[test]
    fn duplicate_points_keep_the_strongest_margin() {
        let pts = SplitPoints::new(vec![(14, 1.1), (14, 2.0)]);
        assert_eq!(pts.len(), 1);
        assert_eq!(pts.first_crossed(10..=14), Some((14, 2.0)));
    }

    // ---- time-aware flushing ------------------------------------------

    #[test]
    fn flush_window_falls_back_to_the_fixed_window() {
        // No bucket seconds: the fixed window comes back untouched —
        // byte-identical to the pre-telemetry policy.
        let fixed = Duration::from_millis(2);
        let policy = BatchPolicy::with_cap(1000);
        assert_eq!(policy.flush_window(0, fixed), fixed);
        assert_eq!(policy.flush_window(1 << 20, fixed), fixed);
        // Degenerate predictions (zero / non-finite) also fall back.
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let policy = BatchPolicy {
                bucket_seconds: Some(BucketSeconds::from([(20, bad)])),
                ..BatchPolicy::with_cap(1000)
            };
            assert_eq!(policy.flush_window(1 << 20, fixed), fixed);
        }
    }

    #[test]
    fn flush_window_caps_at_the_predicted_round_time() {
        let fixed = Duration::from_millis(2);
        let policy = BatchPolicy {
            // Bucket 14's round is predicted at 0.5 ms, bucket 20's at 1 s.
            bucket_seconds: Some(BucketSeconds::from([(14, 0.0005), (20, 1.0)])),
            ..BatchPolicy::with_cap(1 << 22)
        };
        // A queue in bucket 14: don't hold the fuse past the 0.5 ms round
        // it would save.
        assert_eq!(
            policy.flush_window(10_000, fixed),
            Duration::from_secs_f64(0.0005)
        );
        // A queue in bucket 20: the predicted round dwarfs the window, so
        // the fixed window governs.
        assert_eq!(policy.flush_window(1 << 20, fixed), fixed);
        // Sizes between/outside the swept buckets clamp to the nearest
        // rule, exactly like routing (bucket 16 → nearest-below 14;
        // bucket 24 → nearest-below 20; bucket 10 → nearest-above 14).
        assert_eq!(
            policy.flush_window(1 << 16, fixed),
            Duration::from_secs_f64(0.0005)
        );
        assert_eq!(policy.flush_window(1 << 24, fixed), fixed);
        assert_eq!(
            policy.flush_window(100, fixed),
            Duration::from_secs_f64(0.0005)
        );
    }

    #[test]
    fn flush_window_clamps_tiny_predictions_to_the_floor() {
        // A table predicting a 2 µs round for a small bucket must not
        // collapse the window into a busy spin: the wait clamps up to
        // the policy floor (100 µs default), still capped by the fixed
        // window.
        let fixed = Duration::from_millis(2);
        let policy = BatchPolicy {
            bucket_seconds: Some(BucketSeconds::from([(12, 2e-6)])),
            ..BatchPolicy::with_cap(1 << 22)
        };
        assert_eq!(policy.flush_window(3000, fixed), DEFAULT_FLUSH_FLOOR);
        // The floor is configurable…
        let policy = BatchPolicy {
            flush_floor: Duration::from_micros(250),
            ..policy
        };
        assert_eq!(
            policy.flush_window(3000, fixed),
            Duration::from_micros(250)
        );
        // …and never *extends* a fixed window that is already shorter
        // than the floor: the operator's flush_after still governs.
        let tight = Duration::from_micros(50);
        assert_eq!(policy.flush_window(3000, tight), tight);
        // Predictions above the floor are untouched by the clamp.
        let policy = BatchPolicy {
            bucket_seconds: Some(BucketSeconds::from([(12, 0.0005)])),
            ..BatchPolicy::with_cap(1 << 22)
        };
        assert_eq!(
            policy.flush_window(3000, fixed),
            Duration::from_secs_f64(0.0005)
        );
    }

    #[test]
    fn with_table_wires_split_points_and_bucket_seconds_together() {
        let table = table_from_choices(
            Metric::Model,
            &[
                ("x", 10, "cps", 0.0005, 0.6),
                ("x", 15, "ring", 1.0, 1.3),
            ],
        );
        let policy = BatchPolicy::with_cap(1 << 22).with_table(&table, "x");
        assert_eq!(policy.selection.as_ref().unwrap().len(), 1);
        let secs = policy.bucket_seconds.as_ref().unwrap();
        assert_eq!(secs.len(), 2);
        assert_eq!(secs[&10], 0.0005);
        assert_eq!(secs[&15], 1.0);
        // The cap bites in the small-bucket regime only.
        let fixed = Duration::from_millis(2);
        assert_eq!(
            policy.flush_window(1000, fixed),
            Duration::from_secs_f64(0.0005)
        );
        assert_eq!(policy.flush_window(1 << 15, fixed), fixed);
    }

    #[test]
    fn rule_display_is_stable() {
        assert_eq!(BatchRule::FusedToCap.to_string(), "fused-to-cap");
        assert_eq!(
            BatchRule::SplitAtBucket { bucket: 13, margin: 3.0 }.to_string(),
            "split-at-bucket(2^13, 3.00x)"
        );
        assert_eq!(BatchRule::Oversized.name(), "oversized");
        assert_eq!(BatchRule::Drained.name(), "drained");
    }
}
