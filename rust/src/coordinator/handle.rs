//! The hot-swappable selection table: one epoch-versioned handle shared
//! by every consumer of the table, so a recalibration can replace the
//! routing policy of a *running* service atomically.
//!
//! Before the autopilot, the selection table was construction-time
//! configuration: `ServiceConfig::with_selection_table` froze the
//! router's bucket rules, the batcher's split points, and the time-aware
//! flush windows at service start, and recalibrating meant restarting
//! `serve` with a new file. [`TableHandle`] replaces that frozen copy
//! with an `RwLock<Arc<TableView>>`:
//!
//! * a [`TableView`] bundles **one epoch** with every derived per-class
//!   view of **one table** — the router's [`SelectionRules`], the
//!   batcher's [`SplitPoints`], and the flush windows' [`BucketSeconds`]
//!   are all computed from the same `Arc<SelectionTable>` at swap time,
//!   so the three consumers cannot observe mixed generations: whoever
//!   holds a view holds a consistent one;
//! * [`TableHandle::swap`] validates the incoming table (a stored
//!   algorithm that no longer parses is a typed error and the active
//!   table stays in place), then replaces the view in one write-lock
//!   and bumps the epoch — readers never block on derivation work;
//! * the coordinator's leader reads the view once per flush cycle, so
//!   within a cycle routing, splitting, and flushing agree on the epoch,
//!   and every [`super::JobResult`] reports the epoch that served it.
//!
//! Swap-time cache hygiene lives in
//! [`super::PlanRouter::evict_stale`]: entries whose bucket's winner
//! changed between the old and new view are dropped, counted by the
//! `drift_evictions` metric.

use std::sync::{Arc, RwLock};

use crate::api::{AlgoSpec, ApiError};
use crate::campaign::SelectionTable;

use super::batcher::{BatchPolicy, BucketSeconds, SplitPoints};
use super::router::{nearest_bucket, SelectionRules};

/// One coherent generation of the selection policy: the epoch, the table
/// it came from, and every per-class view the serving loop consumes —
/// derived together, immutable once published.
#[derive(Debug, Clone)]
pub struct TableView {
    /// Swap generation: 0 at service start, +1 per successful swap.
    pub epoch: u64,
    /// The topology class the per-class views below are derived for.
    pub class: String,
    pub table: Arc<SelectionTable>,
    /// Router bucket→algorithm rules (`SelectionTable::rules_for`).
    pub rules: SelectionRules,
    /// Batcher winner-change boundaries (`SplitPoints::from_table`).
    pub splits: SplitPoints,
    /// Per-bucket predicted round seconds for time-aware flushing.
    pub bucket_seconds: BucketSeconds,
}

impl TableView {
    fn derive(epoch: u64, class: &str, table: Arc<SelectionTable>) -> Result<TableView, ApiError> {
        let rules = table.rules_for(class)?;
        if rules.is_empty() {
            return Err(ApiError::BadRequest {
                reason: format!("selection table has no entries for topology class {class:?}"),
            });
        }
        Ok(TableView {
            epoch,
            class: class.to_string(),
            splits: SplitPoints::from_table(&table, class),
            bucket_seconds: table.bucket_seconds_for(class),
            rules,
            table,
        })
    }

    /// The algorithm this view routes a payload in `bucket` to (the
    /// nearest-rule clamp routing uses). `None` never happens for a
    /// derived view (rules are non-empty by construction).
    pub fn winner_for(&self, bucket: u32) -> Option<&AlgoSpec> {
        nearest_bucket(&self.rules, bucket)
    }

    /// `base` with this view's split points and bucket seconds overlaid —
    /// the effective batching policy of this epoch. The cap, margin
    /// threshold, and flush floor stay the operator's.
    pub fn overlay(&self, base: &BatchPolicy) -> BatchPolicy {
        BatchPolicy {
            selection: Some(self.splits.clone()),
            bucket_seconds: Some(self.bucket_seconds.clone()),
            ..base.clone()
        }
    }
}

/// The epoch-versioned, swappable selection table (see module docs).
#[derive(Debug)]
pub struct TableHandle {
    state: RwLock<Arc<TableView>>,
}

impl TableHandle {
    /// Wrap `table` at epoch 0, deriving the per-class views for
    /// `class`. Errors mirror `ServiceConfig::with_selection_table`: an
    /// unknown class or a stored algorithm the registry no longer parses.
    pub fn new(table: SelectionTable, class: &str) -> Result<TableHandle, ApiError> {
        Ok(TableHandle {
            state: RwLock::new(Arc::new(TableView::derive(0, class, Arc::new(table))?)),
        })
    }

    /// The current view — one read-lock, one `Arc` clone. A poisoned
    /// lock is recovered (views are immutable, so the data is intact).
    pub fn view(&self) -> Arc<TableView> {
        self.state
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// The current epoch.
    pub fn epoch(&self) -> u64 {
        self.view().epoch
    }

    /// Atomically replace the table, bumping the epoch. The new view is
    /// derived (and validated) for the same class as the active one;
    /// on error the active table keeps serving untouched. Returns the
    /// `(old, new)` views so the caller can reconcile caches.
    pub fn swap(
        &self,
        table: SelectionTable,
    ) -> Result<(Arc<TableView>, Arc<TableView>), ApiError> {
        // Derive outside the write lock — rules_for re-parses every
        // cell's algorithm string, and readers must not block on that.
        // Only the epoch assignment and the publish hold the lock, so a
        // second swapper cannot clash epochs with the first.
        let class = self.view().class.clone();
        let derived = TableView::derive(0, &class, Arc::new(table))?;
        let mut guard = self.state.write().unwrap_or_else(|e| e.into_inner());
        let new = Arc::new(TableView {
            epoch: guard.epoch + 1,
            ..derived
        });
        let old = std::mem::replace(&mut *guard, new.clone());
        Ok((old, new))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::{table_from_choices, table_from_entries, Metric};

    fn two_cell_table() -> SelectionTable {
        table_from_choices(
            Metric::Model,
            &[
                ("single:8", 10, "cps", 0.002, 0.006),
                ("single:8", 17, "ring", 0.5, 1.1),
            ],
        )
    }

    #[test]
    fn view_is_coherent_across_all_three_consumers() {
        // One swap generation = one struct: the rules, split points, and
        // bucket seconds a view hands out are derived from the same
        // table at the same epoch — the coherence the acceptance
        // criterion asks the consumers to observe.
        let h = TableHandle::new(two_cell_table(), "single:8").unwrap();
        let v = h.view();
        assert_eq!(v.epoch, 0);
        assert_eq!(v.class, "single:8");
        assert_eq!(v.rules.len(), 2);
        assert_eq!(v.winner_for(10), Some(&crate::api::AlgoSpec::Cps));
        assert_eq!(v.winner_for(30), Some(&crate::api::AlgoSpec::Ring));
        assert_eq!(v.splits.first_crossed(10..=17), Some((17, 3.0)));
        assert_eq!(v.bucket_seconds[&10], 0.002);
        assert_eq!(v.bucket_seconds[&17], 0.5);
    }

    #[test]
    fn swap_bumps_the_epoch_and_rederives_every_view() {
        let h = TableHandle::new(two_cell_table(), "single:8").unwrap();
        let flipped = table_from_choices(
            Metric::Model,
            &[
                ("single:8", 10, "ring", 0.003, 0.009),
                ("single:8", 17, "cps", 0.4, 0.8),
            ],
        );
        let (old, new) = h.swap(flipped).unwrap();
        assert_eq!((old.epoch, new.epoch), (0, 1));
        assert_eq!(h.epoch(), 1);
        let v = h.view();
        assert_eq!(v.winner_for(10), Some(&crate::api::AlgoSpec::Ring));
        assert_eq!(v.winner_for(17), Some(&crate::api::AlgoSpec::Cps));
        assert_eq!(v.bucket_seconds[&10], 0.003);
        // Old views stay alive and untouched for holders mid-cycle.
        assert_eq!(old.winner_for(10), Some(&crate::api::AlgoSpec::Cps));
    }

    #[test]
    fn bad_swaps_are_typed_errors_and_keep_the_active_table() {
        let h = TableHandle::new(two_cell_table(), "single:8").unwrap();
        // A table that dropped the class entirely.
        let other = table_from_entries(Metric::Model, &[("ss24", 10, "ring")]);
        assert!(matches!(
            h.swap(other),
            Err(ApiError::BadRequest { .. })
        ));
        // A table whose stored algorithm no longer parses.
        let stale = table_from_entries(Metric::Model, &[("single:8", 10, "warpdrive")]);
        assert!(matches!(h.swap(stale), Err(ApiError::UnknownAlgo { .. })));
        // The epoch did not move and the original table still serves.
        assert_eq!(h.epoch(), 0);
        assert_eq!(h.view().winner_for(10), Some(&crate::api::AlgoSpec::Cps));
    }

    #[test]
    fn new_validates_like_with_selection_table() {
        assert!(matches!(
            TableHandle::new(two_cell_table(), "absent"),
            Err(ApiError::BadRequest { .. })
        ));
        let stale = table_from_entries(Metric::Model, &[("x", 10, "warpdrive")]);
        assert!(matches!(
            TableHandle::new(stale, "x"),
            Err(ApiError::UnknownAlgo { .. })
        ));
    }

    #[test]
    fn overlay_keeps_the_operator_knobs() {
        let h = TableHandle::new(two_cell_table(), "single:8").unwrap();
        let base = BatchPolicy::with_cap(12345);
        let policy = h.view().overlay(&base);
        assert_eq!(policy.bucket_floats, 12345);
        assert_eq!(policy.min_split_margin, base.min_split_margin);
        assert_eq!(policy.flush_floor, base.flush_floor);
        assert_eq!(policy.selection.as_ref().unwrap().len(), 1);
        assert_eq!(policy.bucket_seconds.as_ref().unwrap().len(), 2);
    }
}
