//! Per-worker state: block-indexed partial buffers.

use std::collections::HashMap;

use crate::plan::{BlockId, Plan};

/// One worker's view of the payload during plan execution.
#[derive(Debug, Clone, Default)]
pub struct WorkerState {
    /// Current partial (or final) value per block.
    pub partials: HashMap<BlockId, Vec<f32>>,
}

impl WorkerState {
    /// Initialize from this worker's full input vector: every block is a
    /// partial consisting of the worker's own data slice.
    pub fn from_input(plan: &Plan, input: &[f32]) -> WorkerState {
        let s = input.len();
        let mut partials = HashMap::new();
        for b in 0..plan.n_blocks {
            let off = plan.block_offset(b, s);
            let len = plan.block_len(b, s);
            partials.insert(b, input[off..off + len].to_vec());
        }
        WorkerState { partials }
    }

    /// Reassemble the full vector after AllReduce (every block final).
    pub fn assemble(&self, plan: &Plan, s: usize) -> Option<Vec<f32>> {
        let mut out = vec![0f32; s];
        for b in 0..plan.n_blocks {
            let part = self.partials.get(&b)?;
            let off = plan.block_offset(b, s);
            let len = plan.block_len(b, s);
            if part.len() != len {
                return None;
            }
            out[off..off + len].copy_from_slice(part);
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_blocks() {
        let plan = Plan::new("t", 3, 3);
        let input: Vec<f32> = (0..10).map(|x| x as f32).collect();
        let w = WorkerState::from_input(&plan, &input);
        assert_eq!(w.partials.len(), 3);
        assert_eq!(w.partials[&0].len(), 4); // 10 = 4 + 3 + 3
        assert_eq!(w.assemble(&plan, 10).unwrap(), input);
    }

    #[test]
    fn assemble_fails_on_missing_block() {
        let plan = Plan::new("t", 2, 2);
        let mut w = WorkerState::from_input(&plan, &[1.0, 2.0]);
        w.partials.remove(&1);
        assert!(w.assemble(&plan, 2).is_none());
    }
}
