//! Real data-plane execution of AllReduce plans.
//!
//! N in-process workers hold real f32 buffers; plan phases move actual
//! data between them and reduce through the PJRT runtime — the same IR
//! the cost model and simulator consume, now with numbers instead of
//! bitsets. `verify` checks every worker ends with the exact global sum.

pub mod executor;
pub mod worker;

pub use executor::{execute_plan, oracle_sum, verify, ExecOutcome, PhaseStat};
pub use worker::WorkerState;
