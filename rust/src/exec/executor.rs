//! Phase-by-phase plan execution over real buffers.
//!
//! Mirrors the validator's semantics exactly (snapshot sends → apply
//! moves → merge arrivals), with the merges performed by the PJRT
//! fan-in-k reducer — so the δ-relevant fused reduction is the same code
//! path GenModel reasons about.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::plan::ir::{Mode, Plan};
use crate::runtime::Reducer;

use super::worker::WorkerState;

/// Per-phase execution accounting — one entry per plan phase, in phase
/// order. Feeds the flight recorder's `phase` spans so a trace can
/// attribute each phase's wall time to GenModel terms.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseStat {
    /// Floats moved by this phase's transfers (moves + copies).
    pub floats_moved: usize,
    /// Largest merge fan-in in this phase (0: no reduction happened).
    pub max_fanin: usize,
    /// Reducer invocations in this phase.
    pub reduce_calls: usize,
    /// Wall-clock nanoseconds this phase took in-process. This measures
    /// the real memory/reduction path (the δ term's substrate) — the
    /// wire/incast terms are simulated, not incurred, in-process.
    pub wall_ns: u64,
}

/// Execution result.
pub struct ExecOutcome {
    /// Final full vector per worker.
    pub outputs: Vec<Vec<f32>>,
    /// Total reduce invocations and reduced floats (perf accounting).
    pub reduce_calls: usize,
    pub reduced_floats: usize,
    /// Max fan-in encountered (sanity vs plan stats).
    pub max_fanin: usize,
    /// Per-phase accounting, one entry per plan phase in order.
    pub phases: Vec<PhaseStat>,
}

/// Execute an AllReduce plan over `inputs` (one vector per worker, equal
/// lengths). Returns each worker's final vector = element-wise sum of all
/// inputs.
pub fn execute_plan(plan: &Plan, inputs: &[Vec<f32>], reducer: &Reducer) -> Result<ExecOutcome> {
    if inputs.len() != plan.n_servers {
        bail!(
            "plan expects {} workers, got {}",
            plan.n_servers,
            inputs.len()
        );
    }
    let s = inputs[0].len();
    for (i, x) in inputs.iter().enumerate() {
        if x.len() != s {
            bail!("worker {i} input length {} != {}", x.len(), s);
        }
    }
    let mut workers: Vec<WorkerState> = inputs
        .iter()
        .map(|x| WorkerState::from_input(plan, x))
        .collect();

    let mut reduce_calls = 0usize;
    let mut reduced_floats = 0usize;
    let mut max_fanin = 0usize;
    let mut phases: Vec<PhaseStat> = Vec::with_capacity(plan.phases.len());

    for (pi, phase) in plan.phases.iter().enumerate() {
        let phase_start = Instant::now();
        let mut stat = PhaseStat::default();
        // 1. snapshot sends. A `Move` relinquishes the sender's partial,
        // so the buffer is *taken* (no clone — §Perf: halves executor
        // memcpy); valid plans never move the same partial twice in a
        // phase (the validator rejects the double-count). `Copy` sources
        // keep their value and must clone.
        let mut inbox: HashMap<(usize, usize), Vec<Vec<f32>>> = HashMap::new();
        let mut copies: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
        for t in &phase.transfers {
            match t.mode {
                Mode::Move => {
                    let val = workers[t.src]
                        .partials
                        .remove(&t.block)
                        .with_context(|| format!("phase {pi}: {t:?} source missing block"))?;
                    stat.floats_moved += val.len();
                    inbox.entry((t.dst, t.block)).or_default().push(val);
                }
                Mode::Copy => {
                    let val = workers[t.src]
                        .partials
                        .get(&t.block)
                        .with_context(|| format!("phase {pi}: {t:?} source missing block"))?
                        .clone();
                    stat.floats_moved += val.len();
                    copies.insert((t.dst, t.block), val);
                }
            }
        }
        // 3. merge arrivals (deterministic order)
        let mut keys: Vec<(usize, usize)> = inbox.keys().copied().collect();
        keys.sort_unstable();
        for key in keys {
            let (dst, b) = key;
            let mut bufs = inbox.remove(&key).unwrap();
            if let Some(own) = workers[dst].partials.remove(&b) {
                bufs.push(own);
            }
            let merged = if bufs.len() == 1 {
                bufs.pop().unwrap()
            } else {
                let refs: Vec<&[f32]> = bufs.iter().map(|v| v.as_slice()).collect();
                reduce_calls += 1;
                reduced_floats += refs.len() * refs[0].len();
                max_fanin = max_fanin.max(refs.len());
                stat.reduce_calls += 1;
                stat.max_fanin = stat.max_fanin.max(refs.len());
                reducer.reduce(&refs)?
            };
            workers[dst].partials.insert(b, merged);
        }
        // 4. store copies (AllGather deliveries replace any stale value)
        for ((dst, b), val) in copies {
            workers[dst].partials.insert(b, val);
        }
        stat.wall_ns = phase_start.elapsed().as_nanos() as u64;
        phases.push(stat);
    }

    let outputs = workers
        .iter()
        .map(|w| {
            w.assemble(plan, s)
                .context("worker missing blocks after AllReduce")
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ExecOutcome {
        outputs,
        reduce_calls,
        reduced_floats,
        max_fanin,
        phases,
    })
}

/// Exact oracle: f64-accumulated element-wise sum of all inputs.
pub fn oracle_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
    let s = inputs[0].len();
    let mut acc = vec![0f64; s];
    for x in inputs {
        for (a, v) in acc.iter_mut().zip(x) {
            *a += *v as f64;
        }
    }
    acc.into_iter().map(|x| x as f32).collect()
}

/// Verify an execution outcome against the oracle within tolerance.
pub fn verify(outcome: &ExecOutcome, inputs: &[Vec<f32>], rtol: f32) -> Result<()> {
    let want = oracle_sum(inputs);
    for (wi, out) in outcome.outputs.iter().enumerate() {
        for (i, (x, y)) in out.iter().zip(&want).enumerate() {
            let tol = rtol * y.abs().max(1.0);
            if (x - y).abs() > tol {
                bail!("worker {wi} element {i}: {x} vs oracle {y}");
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{cps, hcps, reduce_broadcast, rhd, ring};
    use crate::util::rng::Rng;

    fn inputs(n: usize, s: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.f32_vec(s)).collect()
    }

    fn run_and_verify(plan: &crate::plan::Plan, n: usize, s: usize) {
        let data = inputs(n, s, 42 + n as u64 + s as u64);
        let out = execute_plan(plan, &data, &Reducer::Scalar).unwrap();
        verify(&out, &data, 1e-4).unwrap();
    }

    #[test]
    fn all_baselines_produce_correct_sums() {
        for n in [2usize, 4, 5, 8, 12] {
            run_and_verify(&cps::allreduce(n), n, 1000 + n);
            run_and_verify(&ring::allreduce(n), n, 1000 + n);
            run_and_verify(&rhd::allreduce(n), n, 1000 + n);
            run_and_verify(&reduce_broadcast::allreduce(n), n, 1000 + n);
        }
        run_and_verify(&hcps::allreduce(&[6, 2]), 12, 997);
        run_and_verify(&hcps::allreduce(&[2, 2, 3]), 12, 1024);
    }

    #[test]
    fn gentree_plans_produce_correct_sums() {
        use crate::model::params::Environment;
        use crate::topo::builders::*;
        let env = Environment::paper();
        for topo in [single_switch(9), symmetric(2, 4), cross_dc(&[3], &[2])] {
            let out = crate::gentree::generate(&topo, &env, 1e5);
            run_and_verify(&out.plan, topo.n_servers(), 503);
        }
    }

    #[test]
    fn payload_not_divisible_by_blocks() {
        // 12 blocks, payload 997 floats: uneven blocks exercised.
        run_and_verify(&cps::allreduce(12), 12, 997);
    }

    #[test]
    fn tiny_payload_fewer_floats_than_blocks() {
        run_and_verify(&cps::allreduce(8), 8, 5); // some blocks empty
    }

    #[test]
    fn fanin_matches_plan_structure() {
        let n = 8;
        let data = inputs(n, 64, 9);
        let out = execute_plan(&cps::allreduce(n), &data, &Reducer::Scalar).unwrap();
        assert_eq!(out.max_fanin, n);
        let out = execute_plan(&ring::allreduce(n), &data, &Reducer::Scalar).unwrap();
        assert_eq!(out.max_fanin, 2);
    }

    #[test]
    fn per_phase_stats_cover_every_phase_and_sum_to_the_totals() {
        let n = 8;
        let plan = ring::allreduce(n);
        let data = inputs(n, 64, 9);
        let out = execute_plan(&plan, &data, &Reducer::Scalar).unwrap();
        assert_eq!(out.phases.len(), plan.phases.len());
        let calls: usize = out.phases.iter().map(|p| p.reduce_calls).sum();
        assert_eq!(calls, out.reduce_calls);
        let fanin = out.phases.iter().map(|p| p.max_fanin).max().unwrap();
        assert_eq!(fanin, out.max_fanin);
        // Every ring phase moves data; reduce-scatter phases also reduce.
        assert!(out.phases.iter().all(|p| p.floats_moved > 0));
        assert!(out.phases[0].reduce_calls > 0, "first phase reduce-scatters");
    }

    #[test]
    fn wrong_worker_count_rejected() {
        let data = inputs(3, 8, 1);
        assert!(execute_plan(&cps::allreduce(4), &data, &Reducer::Scalar).is_err());
    }

    #[test]
    fn ragged_inputs_rejected() {
        let mut data = inputs(4, 8, 1);
        data[2].pop();
        assert!(execute_plan(&cps::allreduce(4), &data, &Reducer::Scalar).is_err());
    }
}
