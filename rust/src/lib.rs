//! GenModel / GenTree — reproduction of *Revisiting the Time Cost Model of
//! AllReduce* (CS.DC 2024).
//!
//! Crate layout (three-layer architecture; python/JAX/Pallas only in the
//! compile path, never at runtime):
//!
//! * [`api`] — **the front door**: the [`api::Engine`] facade over one
//!   algorithm registry ([`api::AlgoSpec`] + [`api::registry`]), three
//!   evaluation backends ([`api::Backend`]: analytic / simulated /
//!   executed) returning one [`api::Evaluation`] report, and the typed
//!   [`api::ApiError`] threaded end-to-end (CLI, coordinator, benches).
//! * [`model`] — GenModel: the `(α, β, γ, δ, ε, w_t)` time-cost model,
//!   closed-form expressions (paper Tables 1–2), cost evaluation of
//!   arbitrary plans, and the parameter-fitting toolkit (§3.4).
//! * [`topo`] — physical fabrics behind one [`topo::Fabric`] abstraction:
//!   the paper's rooted-tree topologies (single-switch, symmetric /
//!   asymmetric hierarchical, cross-DC, fat-tree reduction) plus 2-D
//!   mesh / torus grids, each exposing the same server-set, link-class,
//!   and path queries to the model, simulator, and planner.
//! * [`plan`] — the AllReduce plan IR plus every plan builder:
//!   Reduce-Broadcast, Co-located PS, Ring, RHD, Hierarchical CPS,
//!   Asymmetric CPS, the wafer-style mesh schedule, and the generalized
//!   mixed-radix exchange.
//! * [`gentree`] — the paper's plan-generation heuristic (Algorithms 1–2).
//! * [`sim`] — incast-aware event-driven flow-level network simulator (§5.3).
//! * [`runtime`] — PJRT runtime: loads the AOT HLO artifacts and exposes a
//!   fan-in-k reducer to the data plane.
//! * [`exec`] — real data-plane executor: in-process workers with real
//!   buffers; numerics verified against an exact oracle.
//! * [`coordinator`] — the L3 service: job queue, size-bucketing batcher,
//!   plan cache/router (optionally driven by a campaign selection table),
//!   metrics with the per-job queued → drained → batched → executed
//!   lifecycle decomposition and SLO burn-rate monitoring (`repro
//!   status` renders the whole observability surface in one snapshot).
//! * [`campaign`] — parallel (topology × size × algorithm) scenario
//!   sweeps producing JSONL artifacts and the [`campaign::SelectionTable`]
//!   that precomputes the best algorithm per (topology class, size
//!   bucket) for the coordinator's router.
//! * [`telemetry`] — the serving path measures itself: per-(class,
//!   bucket, algorithm) latency histograms fed by the coordinator,
//!   scored against campaign predictions (`repro score`), and refit into
//!   a recalibrated selection table (`repro calibrate`) — campaign →
//!   serve → measure → refit → reselect.
//! * [`fleet`] — N topology-class services behind one telemetry plane
//!   (`repro fleet`): a controller registry of epoch-versioned table
//!   handles and a fleet monitor that pools cross-class observations
//!   into the §3.4 fit and pushes recalibrated tables to every rack.
//! * [`trace`] — phase-level flight recorder (`repro trace`): a bounded
//!   lock-free span ring fed by the coordinator/fleet, each execution
//!   span attributed to the GenModel terms (α / wire / incast / memory),
//!   exported as `trace/v1` JSONL or Chrome trace-event JSON.
//! * [`bench`] — the harness that regenerates every paper table and figure.
//! * [`util`] — substrates built in-repo because the build is offline:
//!   JSON, CLI args, stats, PRNG, property testing, a bench harness.

pub mod api;
pub mod bench;
pub mod campaign;
pub mod coordinator;
pub mod exec;
pub mod fleet;
pub mod gentree;
pub mod model;
pub mod plan;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod topo;
pub mod trace;
pub mod util;
