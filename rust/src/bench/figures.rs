//! Figure regeneration (Figs. 3, 4, 8, 9, 10).

use crate::model::cost::{CostModel, ModelKind};
use crate::model::params::Environment;
use crate::plan::ir::{Mode, Plan};
use crate::plan::{cps, hcps, ring};
use crate::runtime::reducer::{scalar_reduce, scalar_reduce_chained};
use crate::sim::report::{accuracy_row, breakdown_row, term_breakdown};
use crate::sim::{simulate_plan, SimConfig};
use crate::topo::builders::single_switch;
use crate::util::rng::Rng;
use crate::util::table::{secs, Table};

/// Fig. 3: x-to-1 incast — extra communication overhead and the PFC
/// pause-frame analogue, x = 2..=15, S = 20 M floats per sender.
pub fn fig3_incast() -> Table {
    let env = Environment::paper();
    let s = 2e7;
    let mut t = Table::new(
        "Figure 3 — x-to-1 incast: extra overhead & pause-frame analogue (S=20M floats)",
        &["x", "time (s)", "no-incast time (s)", "extra (s)", "pause units"],
    );
    for x in 2..=15usize {
        let topo = single_switch(x + 1);
        // x senders (servers 1..=x) move the whole payload to server 0.
        let mut plan = Plan::new(format!("{x}-to-1"), x + 1, 1);
        {
            let ph = plan.phase();
            for i in 1..=x {
                ph.push(i, 0, 0, Mode::Move);
            }
        }
        let r = simulate_plan(&plan, s, &topo, &env, &SimConfig::new(&topo));
        // No-incast reference: serve the same volume at pure β.
        let p = env.flat(crate::model::params::LinkClass::Server);
        let baseline = p.alpha + x as f64 * s * p.beta;
        let comm = r.communication;
        t.row(vec![
            x.to_string(),
            secs(comm),
            secs(baseline),
            secs((comm - baseline).max(0.0)),
            format!("{:.3}", r.pause_units),
        ]);
    }
    t
}

/// One Fig. 4 sample: average per-add time of reducing x vectors at once
/// (fused single pass) vs pairwise chained, measured for real.
pub fn fig4_sample(x: usize, n: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let rows: Vec<Vec<f32>> = (0..x).map(|_| rng.f32_vec(n)).collect();
    let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
    let t0 = std::time::Instant::now();
    let a = scalar_reduce(&refs);
    let fused = t0.elapsed().as_secs_f64() / (x - 1) as f64;
    let t1 = std::time::Instant::now();
    let b = scalar_reduce_chained(&refs);
    let chained = t1.elapsed().as_secs_f64() / (x - 1) as f64;
    assert_eq!(a.len(), b.len());
    (fused, chained)
}

/// Fig. 4: measured `T(x)/(x−1)` for the fused (PS-like) and chained
/// (Ring-like) reduction patterns, plus the Eq. 5 model curve
/// `(x+1)/(x−1)·C1 + C2`.
pub fn fig4_memaccess(n: usize) -> Table {
    let mut t = Table::new(
        &format!("Figure 4 — avg per-add reduce cost vs fan-in (vectors of {n} floats, measured)"),
        &["x", "fused T/(x-1) (ms)", "chained T/(x-1) (ms)", "model (x+1)/(x-1)*C1+C2"],
    );
    // Calibrate C1 (=Sδ) and C2 (=Sγ) from the two extreme fused samples.
    let xs: Vec<usize> = (2..=16).collect();
    let samples: Vec<(f64, f64)> = xs
        .iter()
        .map(|&x| {
            // median of 3 runs for stability
            let mut f = Vec::new();
            let mut c = Vec::new();
            for r in 0..3 {
                let (a, b) = fig4_sample(x, n, (x * 31 + r) as u64);
                f.push(a);
                c.push(b);
            }
            (crate::util::stats::median(&f), crate::util::stats::median(&c))
        })
        .collect();
    // Fit Eq. 5 on the fused samples: T/(x-1) = C1·(x+1)/(x−1) + C2.
    let mut a = Vec::new();
    let mut b = Vec::new();
    for (&x, &(fused, _)) in xs.iter().zip(&samples) {
        a.extend([(x as f64 + 1.0) / (x as f64 - 1.0), 1.0]);
        b.push(fused);
    }
    let coef = crate::util::stats::lstsq(&a, 2, &b).unwrap_or(vec![0.0, 0.0]);
    for (&x, &(fused, chained)) in xs.iter().zip(&samples) {
        let model = coef[0] * (x as f64 + 1.0) / (x as f64 - 1.0) + coef[1];
        t.row(vec![
            x.to_string(),
            format!("{:.4}", fused * 1e3),
            format!("{:.4}", chained * 1e3),
            format!("{:.4}", model * 1e3),
        ]);
    }
    t
}

fn fig8_plans(n: usize) -> Vec<Plan> {
    let mut plans = vec![ring::allreduce(n), cps::allreduce(n)];
    for fs in crate::gentree::template::ordered_factorizations(n, 16) {
        if fs.len() == 2 {
            plans.push(hcps::allreduce(&fs));
        }
    }
    plans
}

/// Fig. 8: actual (simulator) vs GenModel vs (α,β,γ) predictions on 12
/// and 15 nodes, S = 1e8.
pub fn fig8_accuracy() -> Table {
    let env = Environment::paper();
    let s = 1e8;
    let mut t = Table::new(
        "Figure 8 — prediction accuracy on 12 and 15 nodes (S=1e8 floats)",
        &["n", "plan", "actual (s)", "GenModel (s)", "err %", "classic (s)", "err %"],
    );
    for n in [12usize, 15] {
        let topo = single_switch(n);
        for plan in fig8_plans(n) {
            let row = accuracy_row(&plan, s, &topo, &env);
            t.row(vec![
                n.to_string(),
                plan.name.clone(),
                secs(row.actual),
                secs(row.genmodel),
                format!("{:.1}", row.genmodel_err() * 100.0),
                secs(row.classic),
                format!("{:.1}", row.classic_err() * 100.0),
            ]);
        }
    }
    t
}

/// Fig. 9: communication vs calculation break-down on 12 processors, at
/// 10 Gbps and 100 Gbps.
pub fn fig9_breakdown() -> Table {
    let s = 1e8;
    let n = 12;
    let topo = single_switch(n);
    let mut t = Table::new(
        "Figure 9 — time break-down, 12 processors (S=1e8 floats)",
        &["net", "plan", "communication (s)", "calculation (s)", "total (s)"],
    );
    for (label, env) in [
        ("10G", Environment::paper()),
        ("100G", Environment::paper_100g()),
    ] {
        for plan in fig8_plans(n) {
            let row = breakdown_row(&plan, s, &topo, &env);
            t.row(vec![
                label.to_string(),
                plan.name.clone(),
                secs(row.communication),
                secs(row.calculation),
                secs(row.total),
            ]);
        }
    }
    t
}

/// Fig. 10: GenModel per-term break-down on 12 processors, 10 Gbps.
pub fn fig10_terms() -> Table {
    let s = 1e8;
    let n = 12;
    let topo = single_switch(n);
    let env = Environment::paper();
    let mut t = Table::new(
        "Figure 10 — GenModel term break-down, 12 processors, 10 Gbps (S=1e8)",
        &["plan", "alpha", "beta", "gamma", "delta", "epsilon", "total (s)"],
    );
    for plan in fig8_plans(n) {
        let c = term_breakdown(&plan, s, &topo, &env);
        t.row(vec![
            plan.name.clone(),
            secs(c.alpha),
            secs(c.beta),
            secs(c.gamma),
            secs(c.delta),
            secs(c.epsilon),
            secs(c.total()),
        ]);
    }
    t
}

/// Classic-model view used by tests: which plan does each model pick?
pub fn best_plan_by_model(n: usize, s: f64, kind: ModelKind) -> String {
    let topo = single_switch(n);
    let env = Environment::paper();
    let cm = CostModel::new(&topo, &env, kind);
    fig8_plans(n)
        .into_iter()
        .min_by(|a, b| {
            cm.plan_total(a, s)
                .partial_cmp(&cm.plan_total(b, s))
                .unwrap()
        })
        .unwrap()
        .name
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_monotone_extra_overhead() {
        let t = fig3_incast();
        assert_eq!(t.rows.len(), 14);
        // Below the threshold: no extra overhead; above: growing.
        let extras: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(extras[0] < 1e-9, "x=2 should be incast-free");
        assert!(extras[6] < 1e-9, "x=8 (w=9) still below threshold");
        assert!(extras[13] > extras[8], "incast grows with x");
        // Pause units appear exactly when extra overhead does.
        let pauses: Vec<f64> = t.rows.iter().map(|r| r[4].parse().unwrap()).collect();
        for (e, p) in extras.iter().zip(&pauses) {
            assert_eq!(*e > 1e-12, *p > 0.0);
        }
    }

    #[test]
    fn fig4_fused_decreases_chained_flat() {
        let t = fig4_memaccess(200_000);
        let fused: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        let chained: Vec<f64> = t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        // Fused per-add cost at x=16 well below x=2 (the 66.7% max saving).
        assert!(
            fused[14] < fused[0] * 0.8,
            "fused x=16 {} !<< x=2 {}",
            fused[14],
            fused[0]
        );
        // Chained cannot show the fused saving: its per-add cost stays
        // above the fused per-add cost at high fan-in (it still touches
        // 3 memory streams per add). Exact flatness is too noisy to
        // assert at micro scale.
        assert!(
            chained[14] > fused[14],
            "chained {} !> fused {} at x=16",
            chained[14],
            fused[14]
        );
    }

    #[test]
    fn fig8_genmodel_predicts_best_classic_does_not() {
        // The headline claim: GenModel picks the true best plan at N=12;
        // the classic model picks CPS (blind to incast/memory terms).
        let n = 15;
        let s = 1e8;
        let gen_best = best_plan_by_model(n, s, ModelKind::GenModel);
        let classic_best = best_plan_by_model(n, s, ModelKind::Classic);
        assert_ne!(gen_best, classic_best);
        assert!(classic_best.contains("CPS"), "classic picks CPS: {classic_best}");
        // And the simulator agrees with GenModel's choice.
        let env = Environment::paper();
        let topo = single_switch(n);
        let cfg = crate::sim::SimConfig::new(&topo);
        let best_sim = fig8_plans(n)
            .into_iter()
            .min_by(|a, b| {
                let ta = crate::sim::simulate_plan(a, s, &topo, &env, &cfg).total;
                let tb = crate::sim::simulate_plan(b, s, &topo, &env, &cfg).total;
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap();
        assert_eq!(best_sim.name, gen_best);
    }

    #[test]
    fn fig9_fig10_render() {
        let t9 = fig9_breakdown();
        assert!(t9.rows.len() >= 8);
        let t10 = fig10_terms();
        // Ring has zero epsilon; CPS has nonzero epsilon at n=12.
        let ring_row = t10.rows.iter().find(|r| r[0].contains("Ring")).unwrap();
        assert_eq!(ring_row[5].parse::<f64>().unwrap(), 0.0);
        let cps_row = t10.rows.iter().find(|r| r[0].contains("CPS")).unwrap();
        assert!(cps_row[5].parse::<f64>().unwrap() > 0.0);
    }
}
