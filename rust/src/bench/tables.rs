//! Table regeneration (Tables 3, 4, 5-fit, 6, 7).

use crate::gentree::{generate, generate_with};
use crate::model::expressions::{genmodel, PlanType};
use crate::model::fit::{fit, BenchRow};
use crate::model::params::{Environment, ModelParams};
use crate::sim::{simulate_plan, SimConfig};
use crate::topo::builders::{gpu_pod, single_switch};
use crate::topo::Topology;
use crate::util::table::{millis, secs, speedup, Table};

use super::workloads::{baselines, paper_env, paper_topology, PAPER_SIZES};

fn sim_total(plan: &crate::plan::Plan, s: f64, topo: &Topology, env: &Environment) -> f64 {
    simulate_plan(plan, s, topo, env, &SimConfig::new(topo)).total
}

/// Table 3: CPU testbed — GenTree vs Co-located PS / Ring / RHD at
/// N = 8, 12, 15, S = 1e8 floats (simulated on Table 5 parameters).
pub fn table3_cpu() -> Table {
    let env = paper_env();
    let s = 1e8;
    let mut t = Table::new(
        "Table 3 — CPU testbed (simulated): time (s) at S=1e8 floats",
        &["algorithm", "8", "12", "15"],
    );
    let ns = [8usize, 12, 15];
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    // GenTree first (its own selection per N).
    let mut gt = Vec::new();
    for &n in &ns {
        let topo = single_switch(n);
        let out = generate(&topo, &env, s);
        gt.push(sim_total(&out.plan, s, &topo, &env));
    }
    rows.push(("GenTree".into(), gt));
    for (name, mk) in [
        ("Co-located PS", PlanType::ColocatedPs),
        ("Ring Allreduce", PlanType::Ring),
        ("RHD", PlanType::Rhd),
    ] {
        let mut vals = Vec::new();
        for &n in &ns {
            let topo = single_switch(n);
            let plan = match mk {
                PlanType::ColocatedPs => crate::plan::cps::allreduce(n),
                PlanType::Ring => crate::plan::ring::allreduce(n),
                PlanType::Rhd => crate::plan::rhd::allreduce(n),
                _ => unreachable!(),
            };
            vals.push(sim_total(&plan, s, &topo, &env));
        }
        rows.push((name.to_string(), vals));
    }
    for (name, vals) in rows {
        t.row(
            std::iter::once(name)
                .chain(vals.iter().map(|v| secs(*v)))
                .collect(),
        );
    }
    t
}

/// Table 4: GPU testbed — GenTree vs NCCL(≈Ring over all GPUs) at 16, 32,
/// 64 GPUs and four data sizes, simulated with GPU-grade parameters.
pub fn table4_gpu() -> Table {
    let env = Environment::gpu();
    let sizes = [1e7, 3.2e7, 1e8, 3.2e8];
    let mut t = Table::new(
        "Table 4 — GPU testbed (simulated): time (ms) per data size (floats)",
        &["#GPUs", "algorithm", "1e7", "3.2e7", "1e8", "3.2e8", "speedup@3.2e8"],
    );
    for machines in [2usize, 4, 8] {
        let topo = gpu_pod(machines, 8);
        let n = topo.n_servers();
        let cfg = SimConfig::new(&topo);
        let gen_times: Vec<f64> = sizes
            .iter()
            .map(|&s| {
                let out = generate(&topo, &env, s);
                simulate_plan(&out.plan, s, &topo, &env, &cfg).total
            })
            .collect();
        let nccl_times: Vec<f64> = sizes
            .iter()
            .map(|&s| {
                let ring = crate::plan::ring::allreduce(n);
                simulate_plan(&ring, s, &topo, &env, &cfg).total
            })
            .collect();
        t.row(
            std::iter::once(n.to_string())
                .chain(std::iter::once("GenTree".to_string()))
                .chain(gen_times.iter().map(|v| millis(*v)))
                .chain(std::iter::once(speedup(
                    nccl_times[3],
                    gen_times[3],
                )))
                .collect(),
        );
        t.row(
            std::iter::once(n.to_string())
                .chain(std::iter::once("NCCL (Ring)".to_string()))
                .chain(nccl_times.iter().map(|v| millis(*v)))
                .chain(std::iter::once("1.00x".to_string()))
                .collect(),
        );
    }
    t
}

/// Table 5: fit the GenModel parameters back from simulated CPS benches
/// (the §3.4 toolkit flow) and compare with the ground-truth inputs.
pub fn table5_fit() -> Table {
    let env = paper_env();
    let truth = ModelParams::cpu_testbed();
    let mut rows = Vec::new();
    for n in 2..=15usize {
        for s in [2e7, 1e8] {
            let topo = single_switch(n);
            let plan = crate::plan::cps::allreduce(n);
            rows.push(BenchRow {
                n,
                s,
                time: sim_total(&plan, s, &topo, &env),
            });
        }
    }
    let f = fit(&rows).expect("fit");
    let mut t = Table::new(
        "Table 5 — parameters recovered by the fitting toolkit (from simulated CPS benches)",
        &["parameter", "ground truth", "fitted", "rel err %"],
    );
    let rel = |a: f64, b: f64| ((a - b).abs() / b.abs().max(1e-30) * 100.0).min(999.0);
    t.row(vec![
        "alpha".into(),
        format!("{:.3e}", truth.alpha),
        format!("{:.3e}", f.alpha),
        format!("{:.2}", rel(f.alpha, truth.alpha)),
    ]);
    t.row(vec![
        "2*beta+gamma".into(),
        format!("{:.3e}", truth.two_beta_plus_gamma()),
        format!("{:.3e}", f.two_beta_plus_gamma),
        format!("{:.2}", rel(f.two_beta_plus_gamma, truth.two_beta_plus_gamma())),
    ]);
    t.row(vec![
        "delta".into(),
        format!("{:.3e}", truth.delta),
        format!("{:.3e}", f.delta),
        format!("{:.2}", rel(f.delta, truth.delta)),
    ]);
    t.row(vec![
        "epsilon".into(),
        format!("{:.3e}", truth.epsilon),
        format!("{:.3e}", f.epsilon),
        format!("{:.2}", rel(f.epsilon, truth.epsilon)),
    ]);
    t.row(vec![
        "w_t".into(),
        truth.w_t.to_string(),
        f.w_t.to_string(),
        if f.w_t == truth.w_t { "0.00".into() } else { "—".into() },
    ]);
    t
}

/// Table 6: the plan GenTree selects per switch level, per topology and
/// data size.
pub fn table6_selections() -> Table {
    let env = paper_env();
    let mut t = Table::new(
        "Table 6 — AllReduce plans selected by GenTree",
        &["network", "switch level", "1e7", "3.2e7", "1e8"],
    );
    for name in ["ss24", "ss32", "sym384", "sym512", "asy384", "cdc384"] {
        let topo = paper_topology(name).unwrap();
        // Collect per-(depth, choice-at-that-depth) across sizes. Group
        // switches by (depth, subtree size) like the paper's rows.
        let mut level_choices: std::collections::BTreeMap<String, Vec<String>> =
            Default::default();
        for &s in &PAPER_SIZES {
            let out = generate(&topo, &env, s);
            let mut per_level: std::collections::BTreeMap<String, String> = Default::default();
            for sel in &out.selections {
                let label = match (sel.depth, topo.node(sel.switch).children.len()) {
                    (0, _) => "Root SW".to_string(),
                    (d, _) => format!("L{d} SW ({})", sel.switch_name),
                };
                let entry = per_level.entry(level_key(&topo, sel)).or_insert_with(|| {
                    let _ = label;
                    sel.choice.clone()
                });
                // If switches at the same level pick different plans
                // (asymmetric networks), note both.
                if *entry != sel.choice && !entry.contains(&sel.choice) {
                    entry.push('/');
                    entry.push_str(&sel.choice);
                }
            }
            for (level, choice) in per_level {
                level_choices.entry(level).or_default().push(choice);
            }
        }
        for (level, choices) in level_choices {
            // choices has one entry per size.
            let mut row = vec![name.to_uppercase(), level];
            row.extend(choices);
            while row.len() < 5 {
                row.push("—".into());
            }
            t.row(row);
        }
    }
    t
}

fn level_key(topo: &Topology, sel: &crate::gentree::Selection) -> String {
    if sel.depth == 0 {
        "Root SW".to_string()
    } else {
        let n = topo.servers_under(sel.switch).len();
        format!("L{} SW (n={})", sel.depth, n)
    }
}

/// Table 7: large-scale simulation — GenTree (and GenTree* without
/// rearrangement on CDC) vs the baselines on all six topologies.
pub fn table7_sim() -> Table {
    let env = paper_env();
    let mut t = Table::new(
        "Table 7 — large-scale simulation: time (s) per data size (floats)",
        &["topo", "algorithm", "1e7", "3.2e7", "1e8", "speedup@1e8"],
    );
    for name in ["ss24", "ss32", "sym384", "sym512", "asy384", "cdc384"] {
        let topo = paper_topology(name).unwrap();
        let n = topo.n_servers();
        let cfg = SimConfig::new(&topo);
        let gen_times: Vec<f64> = PAPER_SIZES
            .iter()
            .map(|&s| {
                let out = generate(&topo, &env, s);
                simulate_plan(&out.plan, s, &topo, &env, &cfg).total
            })
            .collect();
        t.row(vec![
            name.to_uppercase(),
            "GenTree".into(),
            secs(gen_times[0]),
            secs(gen_times[1]),
            secs(gen_times[2]),
            "—".into(),
        ]);
        if name == "cdc384" {
            let star: Vec<f64> = PAPER_SIZES
                .iter()
                .map(|&s| {
                    let out = generate_with(
                        &topo,
                        &env,
                        s,
                        &crate::gentree::generate::GenTreeConfig {
                            allow_rearrangement: false,
                            ..Default::default()
                        },
                    );
                    simulate_plan(&out.plan, s, &topo, &env, &cfg).total
                })
                .collect();
            t.row(vec![
                name.to_uppercase(),
                "GenTree*".into(),
                secs(star[0]),
                secs(star[1]),
                secs(star[2]),
                speedup(star[2], gen_times[2]),
            ]);
        }
        for base in baselines(n) {
            let times: Vec<f64> = PAPER_SIZES
                .iter()
                .map(|&s| simulate_plan(&base, s, &topo, &env, &cfg).total)
                .collect();
            let label = if base.name.starts_with("Ring") {
                "Ring Allreduce"
            } else if base.name.starts_with("CPS") {
                "Co-located PS"
            } else {
                "RHD"
            };
            t.row(vec![
                name.to_uppercase(),
                label.into(),
                secs(times[0]),
                secs(times[1]),
                secs(times[2]),
                speedup(times[2], gen_times[2]),
            ]);
        }
    }
    t
}

/// Closed-form sanity table (Tables 1–2 as numbers) — extra diagnostic.
pub fn expressions_table(n: usize, s: f64) -> Table {
    let p = ModelParams::cpu_testbed();
    let mut t = Table::new(
        &format!("Tables 1–2 — closed-form costs at N={n}, S={s:.0e}"),
        &["plan", "classic total (s)", "GenModel total (s)"],
    );
    let mut plans = vec![
        PlanType::ReduceBroadcast,
        PlanType::ColocatedPs,
        PlanType::Ring,
        PlanType::Rhd,
    ];
    for fs in crate::gentree::template::ordered_factorizations(n, 8) {
        if fs.len() == 2 {
            plans.push(PlanType::HierarchicalPs(fs));
        }
    }
    for plan in plans {
        let g = genmodel(&plan, n, s, &p);
        t.row(vec![
            format!("{plan}"),
            secs(g.classic_total()),
            secs(g.total()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_gentree_wins_or_ties() {
        let t = table3_cpu();
        let get = |algo: &str, col: usize| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0] == algo)
                .unwrap()[col]
                .parse()
                .unwrap()
        };
        for col in 1..=3 {
            let g = get("GenTree", col);
            for algo in ["Co-located PS", "Ring Allreduce", "RHD"] {
                assert!(
                    g <= get(algo, col) * 1.001,
                    "col {col}: GenTree {g} vs {algo} {}",
                    get(algo, col)
                );
            }
        }
        // Paper shape: RHD at 12/15 (non-power-of-two) much worse than at 8.
        assert!(get("RHD", 2) > get("RHD", 1) * 1.5);
    }

    #[test]
    fn table5_fit_recovers() {
        let t = table5_fit();
        // w_t row recovered exactly.
        let wt = t.rows.iter().find(|r| r[0] == "w_t").unwrap();
        assert_eq!(wt[1], wt[2]);
        // Compound within 10% (simulator vs closed-form differences).
        let bg = t.rows.iter().find(|r| r[0] == "2*beta+gamma").unwrap();
        let err: f64 = bg[3].parse().unwrap();
        assert!(err < 10.0, "2b+g err {err}%");
    }

    #[test]
    fn table6_shapes() {
        let t = table6_selections();
        // SS32 root at 1e8 must be hierarchical 8x4 (paper Table 6).
        let ss32 = t
            .rows
            .iter()
            .find(|r| r[0] == "SS32" && r[1] == "Root SW")
            .unwrap();
        assert_eq!(ss32[4], "8x4", "{ss32:?}");
        // CDC384 root must use rearrangement (the +R suffix on ACPS/CPS).
        let cdc_root = t
            .rows
            .iter()
            .find(|r| r[0] == "CDC384" && r[1] == "Root SW")
            .unwrap();
        assert!(
            cdc_root[4].contains("+R"),
            "CDC root at 1e8 should rearrange: {cdc_root:?}"
        );
    }

    #[test]
    fn table7_gentree_dominates() {
        let t = table7_sim();
        // For every topology and size, GenTree ≤ every baseline.
        for name in ["SS24", "SS32", "SYM384", "SYM512", "ASY384", "CDC384"] {
            let gen: Vec<f64> = {
                let r = t
                    .rows
                    .iter()
                    .find(|r| r[0] == name && r[1] == "GenTree")
                    .unwrap();
                (2..5).map(|i| r[i].parse().unwrap()).collect()
            };
            for row in t.rows.iter().filter(|r| r[0] == name && r[1] != "GenTree") {
                for (i, g) in gen.iter().enumerate() {
                    let v: f64 = row[i + 2].parse().unwrap();
                    assert!(
                        *g <= v * 1.02,
                        "{name} {}: GenTree {g} vs {v}",
                        row[1]
                    );
                }
            }
        }
    }
}
