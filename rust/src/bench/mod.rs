//! Harness regenerating every table and figure of the paper's evaluation
//! (§5). Each function returns a [`crate::util::table::Table`] whose rows
//! mirror the paper's layout; the CLI (`repro reproduce`) prints them and
//! `rust/benches/*` time the underlying computations.

pub mod figures;
pub mod tables;
pub mod workloads;

pub use figures::{fig10_terms, fig3_incast, fig4_memaccess, fig8_accuracy, fig9_breakdown};
pub use tables::{table3_cpu, table4_gpu, table5_fit, table6_selections, table7_sim};
