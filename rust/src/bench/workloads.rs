//! Shared workload definitions for the evaluation harness: the paper's
//! topology instances (§5.3) and baseline plan sets.

use crate::api;
use crate::model::params::Environment;
use crate::plan::Plan;
use crate::topo::{builders, Topology};

/// The six evaluation topologies of Fig. 11 / Table 7, by paper name.
pub fn paper_topology(name: &str) -> Option<Topology> {
    match name.to_ascii_lowercase().as_str() {
        "ss24" => Some(builders::single_switch(24)),
        "ss32" => Some(builders::single_switch(32)),
        "sym384" => Some(builders::symmetric(16, 24)),
        "sym512" => Some(builders::symmetric(16, 32)),
        "asy384" => Some(builders::asymmetric(&[32; 8], &[16; 8])),
        "cdc384" => Some(builders::cross_dc(&[32; 8], &[16; 8])),
        _ => None,
    }
}

/// Parse extended topology specs: paper names plus `single:N`, `sym:M,K`,
/// `gpu:M,G`, `asy:a+b+…/c+d+…`, `cdc:a+b/c+d`.
pub fn parse_topology(spec: &str) -> Option<Topology> {
    if let Some(t) = paper_topology(spec) {
        return Some(t);
    }
    let (kind, rest) = spec.split_once(':')?;
    let nums = |s: &str| -> Option<Vec<usize>> {
        s.split(&['+', ','][..])
            .map(|x| x.trim().parse::<usize>().ok())
            .collect()
    };
    match kind {
        "single" => Some(builders::single_switch(rest.parse().ok()?)),
        "sym" => {
            let v = nums(rest)?;
            (v.len() == 2).then(|| builders::symmetric(v[0], v[1]))
        }
        "gpu" => {
            let v = nums(rest)?;
            (v.len() == 2).then(|| builders::gpu_pod(v[0], v[1]))
        }
        "asy" => {
            let (a, b) = rest.split_once('/')?;
            Some(builders::asymmetric(&nums(a)?, &nums(b)?))
        }
        "cdc" => {
            let (a, b) = rest.split_once('/')?;
            Some(builders::cross_dc(&nums(a)?, &nums(b)?))
        }
        _ => None,
    }
}

/// The three data sizes of the large-scale evaluation (floats).
pub const PAPER_SIZES: [f64; 3] = [1e7, 3.2e7, 1e8];

/// Baseline plans for `n` servers, named as in Table 7 (RHD only for
/// power-of-two n, as in the paper). Enumeration and construction go
/// through the `api` registry — this is just the flat-topology view.
pub fn baselines(n: usize) -> Vec<Plan> {
    api::baseline_plans(&builders::single_switch(n), &Environment::paper(), 1e8)
}

/// The environment used for the CPU-cluster simulations (Table 5 values).
pub fn paper_env() -> Environment {
    Environment::paper()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_names_resolve() {
        for (name, n) in [
            ("ss24", 24usize),
            ("SS32", 32),
            ("sym384", 384),
            ("SYM512", 512),
            ("asy384", 384),
            ("cdc384", 384),
        ] {
            assert_eq!(paper_topology(name).unwrap().n_servers(), n);
        }
        assert!(paper_topology("nope").is_none());
    }

    #[test]
    fn extended_specs() {
        assert_eq!(parse_topology("single:9").unwrap().n_servers(), 9);
        assert_eq!(parse_topology("sym:4,6").unwrap().n_servers(), 24);
        assert_eq!(parse_topology("gpu:2,8").unwrap().n_servers(), 16);
        assert_eq!(parse_topology("asy:4+4/2").unwrap().n_servers(), 10);
        assert_eq!(parse_topology("cdc:4/2+2").unwrap().n_servers(), 8);
        assert!(parse_topology("bogus:1").is_none());
    }

    #[test]
    fn baselines_respect_rhd_rule() {
        assert_eq!(baselines(24).len(), 2); // no RHD
        assert_eq!(baselines(32).len(), 3);
    }
}
