//! Shared workload definitions for the evaluation harness: the paper's
//! topology instances (§5.3) and baseline plan sets.

use crate::api::{self, ApiError};
use crate::model::params::Environment;
use crate::plan::Plan;
use crate::topo::{builders, Fabric, Topology};

/// The six evaluation topologies of Fig. 11 / Table 7, by paper name.
pub fn paper_topology(name: &str) -> Option<Topology> {
    match name.to_ascii_lowercase().as_str() {
        "ss24" => Some(builders::single_switch(24)),
        "ss32" => Some(builders::single_switch(32)),
        "sym384" => Some(builders::symmetric(16, 24)),
        "sym512" => Some(builders::symmetric(16, 32)),
        "asy384" => Some(builders::asymmetric(&[32; 8], &[16; 8])),
        "cdc384" => Some(builders::cross_dc(&[32; 8], &[16; 8])),
        _ => None,
    }
}

/// Parse extended topology specs into a [`Fabric`]: paper names plus
/// `single:N`, `sym:M,K`, `gpu:M,G`, `asy:a+b+…/c+d+…`, `cdc:a+b/c+d`,
/// and the grid fabrics `mesh:RxC` / `torus:RxC` (also accepted as the
/// bare names `MESH4x4` / `TORUS4x4`, case-insensitive).
///
/// Malformed specs (wrong arity, empty sides, non-numeric counts, grid
/// dimensions below 2x2) are typed [`ApiError::BadTopology`] errors
/// naming the offending spec — never a silent `None`.
pub fn parse_topology(spec: &str) -> Result<Fabric, ApiError> {
    let bad = |reason: String| ApiError::BadTopology {
        spec: spec.to_string(),
        reason,
    };
    if let Some(t) = paper_topology(spec) {
        return Ok(t.into());
    }
    // `RxC` grid dimensions for mesh/torus, re-attributing builder
    // errors to the spec the user actually typed.
    let grid = |dims: &str, wrap: bool| -> Result<Fabric, ApiError> {
        let (r, c) = dims
            .split_once('x')
            .ok_or_else(|| bad(format!("expected RxC grid dimensions, got {dims:?}")))?;
        let dim = |x: &str| {
            x.trim()
                .parse::<usize>()
                .map_err(|_| bad(format!("non-numeric grid dimension {x:?}")))
        };
        let m = if wrap {
            builders::torus(dim(r)?, dim(c)?)
        } else {
            builders::mesh(dim(r)?, dim(c)?)
        };
        m.map(Fabric::from).map_err(|e| match e {
            ApiError::BadTopology { reason, .. } => bad(reason),
            other => other,
        })
    };
    let lower = spec.to_ascii_lowercase();
    if !lower.contains(':') {
        for (prefix, wrap) in [("mesh", false), ("torus", true)] {
            if let Some(dims) = lower.strip_prefix(prefix) {
                if dims.contains('x') {
                    return grid(dims, wrap);
                }
            }
        }
    }
    let (kind, rest) = lower.split_once(':').ok_or_else(|| {
        bad(
            "expected a paper name (ss24 ss32 sym384 sym512 asy384 cdc384), a grid \
             name (MESH4x4 TORUS4x4), or kind:params (single:N sym:M,K gpu:M,G \
             asy:a+b/c+d cdc:a+b/c+d mesh:RxC torus:RxC)"
                .into(),
        )
    })?;
    let nums = |s: &str, what: &str| -> Result<Vec<usize>, ApiError> {
        if s.trim().is_empty() {
            return Err(bad(format!("{what} is empty")));
        }
        s.split(&['+', ','][..])
            .map(|x| {
                x.trim()
                    .parse::<usize>()
                    .map_err(|_| bad(format!("{what} has a non-numeric count {x:?}")))
            })
            .collect()
    };
    match kind {
        "single" => {
            let n: usize = rest
                .trim()
                .parse()
                .map_err(|_| bad(format!("single expects a server count, got {rest:?}")))?;
            if n < 2 {
                return Err(bad(format!("single needs ≥ 2 servers, got {n}")));
            }
            Ok(builders::single_switch(n).into())
        }
        "sym" => {
            let v = nums(rest, "sym parameter list")?;
            if v.len() != 2 {
                return Err(bad(format!(
                    "sym expects M,K (switches, servers-per-switch), got {} value(s)",
                    v.len()
                )));
            }
            if v[0] == 0 || v[1] == 0 {
                return Err(bad("sym factors must be positive".into()));
            }
            Ok(builders::symmetric(v[0], v[1]).into())
        }
        "gpu" => {
            let v = nums(rest, "gpu parameter list")?;
            if v.len() != 2 {
                return Err(bad(format!(
                    "gpu expects M,G (machines, gpus-per-machine), got {} value(s)",
                    v.len()
                )));
            }
            if v[0] == 0 || v[1] == 0 {
                return Err(bad("gpu factors must be positive".into()));
            }
            Ok(builders::gpu_pod(v[0], v[1]).into())
        }
        "asy" => {
            let (a, b) = rest
                .split_once('/')
                .ok_or_else(|| bad("asy expects big/small server-count lists".into()))?;
            let big = nums(a, "asy big side")?;
            let small = nums(b, "asy small side")?;
            if big.iter().chain(&small).sum::<usize>() == 0 {
                return Err(bad("asy topology has no servers".into()));
            }
            Ok(builders::asymmetric(&big, &small).into())
        }
        "cdc" => {
            let (a, b) = rest
                .split_once('/')
                .ok_or_else(|| bad("cdc expects dc0/dc1 server-count lists".into()))?;
            let dc0 = nums(a, "cdc first data center")?;
            let dc1 = nums(b, "cdc second data center")?;
            if dc0.iter().chain(&dc1).sum::<usize>() == 0 {
                return Err(bad("cdc topology has no servers".into()));
            }
            Ok(builders::cross_dc(&dc0, &dc1).into())
        }
        "mesh" => grid(rest, false),
        "torus" => grid(rest, true),
        other => Err(bad(format!(
            "unknown topology kind {other:?} (known: single, sym, gpu, asy, cdc, mesh, torus)"
        ))),
    }
}

/// The three data sizes of the large-scale evaluation (floats).
pub const PAPER_SIZES: [f64; 3] = [1e7, 3.2e7, 1e8];

/// Baseline plans for `n` servers, named as in Table 7 (RHD only for
/// power-of-two n, as in the paper). Enumeration and construction go
/// through the `api` registry — this is just the flat-topology view.
pub fn baselines(n: usize) -> Vec<Plan> {
    api::baseline_plans(&builders::single_switch(n), &Environment::paper(), 1e8)
}

/// The environment used for the CPU-cluster simulations (Table 5 values).
pub fn paper_env() -> Environment {
    Environment::paper()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_names_resolve() {
        for (name, n) in [
            ("ss24", 24usize),
            ("SS32", 32),
            ("sym384", 384),
            ("SYM512", 512),
            ("asy384", 384),
            ("cdc384", 384),
        ] {
            assert_eq!(paper_topology(name).unwrap().n_servers(), n);
        }
        assert!(paper_topology("nope").is_none());
    }

    #[test]
    fn extended_specs() {
        assert_eq!(parse_topology("single:9").unwrap().n_servers(), 9);
        assert_eq!(parse_topology("sym:4,6").unwrap().n_servers(), 24);
        assert_eq!(parse_topology("gpu:2,8").unwrap().n_servers(), 16);
        assert_eq!(parse_topology("asy:4+4/2").unwrap().n_servers(), 10);
        assert_eq!(parse_topology("cdc:4/2+2").unwrap().n_servers(), 8);
    }

    #[test]
    fn grid_specs() {
        let m = parse_topology("mesh:4x4").unwrap();
        assert_eq!(m.n_servers(), 16);
        assert_eq!(m.name(), "MESH4x4");
        assert_eq!(parse_topology("torus:3x5").unwrap().n_servers(), 15);
        // Bare paper-style names, case-insensitive.
        assert_eq!(parse_topology("MESH4x4").unwrap().name(), "MESH4x4");
        assert_eq!(parse_topology("torus4X4").unwrap().name(), "TORUS4x4");
        assert!(parse_topology("mesh:2x2").unwrap().as_mesh().is_some());
    }

    #[test]
    fn malformed_specs_are_typed_errors_naming_the_spec() {
        for spec in [
            "bogus:1",     // unknown kind
            "sym:16",      // missing K
            "sym:4,6,8",   // too many values
            "asy:32/",     // empty small side
            "asy:32",      // missing '/'
            "cdc:4",       // missing '/'
            "single:x",    // non-numeric
            "single:1",    // too few servers
            "sym:0,4",     // zero factor
            "asy:a+4/2",   // non-numeric count
            "mesh:4",      // missing xC
            "mesh:1x4",    // dimension below 2
            "torus:0x3",   // zero dimension
            "mesh:axb",    // non-numeric dimension
            "nonsense",    // neither paper name nor kind:params
        ] {
            match parse_topology(spec) {
                Err(ApiError::BadTopology { spec: s, reason }) => {
                    assert_eq!(s, spec);
                    assert!(!reason.is_empty(), "{spec}: empty reason");
                }
                other => panic!("{spec}: expected BadTopology, got {other:?}"),
            }
        }
    }

    #[test]
    fn baselines_respect_rhd_rule() {
        assert_eq!(baselines(24).len(), 2); // no RHD
        assert_eq!(baselines(32).len(), 3);
    }
}
