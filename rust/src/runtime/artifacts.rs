//! Artifact loading: manifest.json + HLO text → compiled PJRT executables.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub chunk_n: usize,
    pub tail_n: usize,
    pub reduce_ks: Vec<usize>,
    /// (kind, k, n) -> file name. k = 0 for kinds without fan-in.
    pub entries: HashMap<(String, usize, usize), String>,
    /// Variants lowered with an *untupled* root (raw-copy IO eligible).
    pub raw: std::collections::HashSet<(String, usize, usize)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("manifest.json parse")?;
        let chunk_n = v
            .get("chunk_n")
            .and_then(Json::as_usize)
            .context("chunk_n")?;
        let tail_n = v.get("tail_n").and_then(Json::as_usize).context("tail_n")?;
        let reduce_ks = v
            .get("reduce_ks")
            .and_then(Json::as_arr)
            .context("reduce_ks")?
            .iter()
            .map(|x| x.as_usize().context("reduce_ks entry"))
            .collect::<Result<Vec<_>>>()?;
        let mut entries = HashMap::new();
        let mut raw = std::collections::HashSet::new();
        for e in v.get("entries").and_then(Json::as_arr).context("entries")? {
            let kind = e.get("kind").and_then(Json::as_str).context("kind")?;
            let file = e.get("file").and_then(Json::as_str).context("file")?;
            let k = e.get("k").and_then(Json::as_usize).unwrap_or(0);
            let n = e.get("n").and_then(Json::as_usize).context("n")?;
            entries.insert((kind.to_string(), k, n), file.to_string());
            if e.get("raw") == Some(&Json::Bool(true)) {
                raw.insert((kind.to_string(), k, n));
            }
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Manifest {
            chunk_n,
            tail_n,
            reduce_ks,
            entries,
            raw,
        })
    }
}

/// Compiled executables on a PJRT CPU client. Only available with the
/// `pjrt` feature (the `xla` bindings are outside the offline dependency
/// closure); without it the reducer falls back to the scalar path.
#[cfg(feature = "pjrt")]
pub struct Artifacts {
    pub manifest: Manifest,
    pub dir: PathBuf,
    client: xla::PjRtClient,
    /// (kind, k, n) -> compiled executable, loaded lazily.
    cache: std::sync::Mutex<HashMap<(String, usize, usize), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "pjrt")]
impl Artifacts {
    /// Default artifact directory: $REPRO_ARTIFACTS or ./artifacts
    /// relative to the crate root.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("REPRO_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let here = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        here.join("artifacts")
    }

    pub fn load_default() -> Result<Artifacts> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Artifacts> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Artifacts {
            manifest,
            dir: dir.to_path_buf(),
            client,
            cache: std::sync::Mutex::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Get (compiling on first use) the executable for (kind, k, n).
    pub fn executable(
        &self,
        kind: &str,
        k: usize,
        n: usize,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = (kind.to_string(), k, n);
        {
            let cache = self.cache.lock().unwrap();
            if let Some(e) = cache.get(&key) {
                return Ok(e.clone());
            }
        }
        let file = self
            .manifest
            .entries
            .get(&key)
            .with_context(|| format!("no artifact for kind={kind} k={k} n={n}"))?;
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute a compiled reduce variant on a (k, n) f32 input (row-major
    /// flat slice of length k·n), writing the n-length sum into `out`.
    ///
    /// Variants flagged `raw` in the manifest (untupled root) take the
    /// §Perf fast path: host slice → device buffer (`buffer_from_host
    /// _buffer`), `execute_b`, and a raw device→host copy — skipping the
    /// Literal reshape/tuple/vec round-trips entirely (~3 extra full-size
    /// copies on 32 MB dispatches).
    pub fn reduce_into(
        &self,
        kind: &str,
        k: usize,
        n: usize,
        flat: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        assert_eq!(flat.len(), k * n);
        assert_eq!(out.len(), n);
        let exe = self.executable(kind, k, n)?;
        if self.manifest.raw.contains(&(kind.to_string(), k, n)) {
            let buf = self
                .client
                .buffer_from_host_buffer(flat, &[k, n], None)
                .map_err(|e| anyhow::anyhow!("buffer_from_host: {e}"))?;
            let result = exe
                .execute_b::<xla::PjRtBuffer>(&[buf])
                .map_err(|e| anyhow::anyhow!("execute_b: {e}"))?;
            // `copy_raw_to_host_sync` is unimplemented on the TFRT CPU
            // client; untupled literal + `copy_raw_to` is the next-best IO
            // (skips the input vec1+reshape literals and tuple unwrap).
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
            lit.copy_raw_to(out)
                .map_err(|e| anyhow::anyhow!("copy_raw_to: {e}"))?;
            return Ok(());
        }
        let x = xla::Literal::vec1(flat)
            .reshape(&[k as i64, n as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e}"))?;
        let result = exe
            .execute::<xla::Literal>(&[x])
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        let res = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("to_tuple1: {e}"))?;
        let v = res
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        out.copy_from_slice(&v);
        Ok(())
    }

    /// Compatibility wrapper returning a fresh Vec.
    pub fn run_reduce(&self, kind: &str, k: usize, n: usize, flat: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; n];
        self.reduce_into(kind, k, n, flat, &mut out)?;
        Ok(out)
    }

    /// Execute the fused sgd_update artifact: w − lr·g over n floats.
    pub fn run_sgd(&self, n: usize, w: &[f32], g: &[f32], lr: f32) -> Result<Vec<f32>> {
        assert_eq!(w.len(), n);
        assert_eq!(g.len(), n);
        let exe = self.executable("sgd", 0, n)?;
        let lw = xla::Literal::vec1(w)
            .reshape(&[n as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e}"))?;
        let lg = xla::Literal::vec1(g)
            .reshape(&[n as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e}"))?;
        let llr = xla::Literal::scalar(lr);
        let result = exe
            .execute::<xla::Literal>(&[lw, lg, llr])
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("to_tuple1: {e}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            r#"{"format":"hlo-text","chunk_n":65536,"tail_n":4096,
                "reduce_ks":[2,3],
                "entries":[
                  {"file":"reduce_k2_n65536.hlo.txt","kind":"reduce","k":2,"n":65536,"sha256":"x"},
                  {"file":"sgd_n65536.hlo.txt","kind":"sgd","n":65536,"sha256":"y"}
                ]}"#,
        )
        .unwrap();
        assert_eq!(m.chunk_n, 65536);
        assert_eq!(m.reduce_ks, vec![2, 3]);
        assert_eq!(
            m.entries[&("reduce".to_string(), 2, 65536)],
            "reduce_k2_n65536.hlo.txt"
        );
        assert_eq!(m.entries[&("sgd".to_string(), 0, 65536)], "sgd_n65536.hlo.txt");
    }

    #[test]
    fn manifest_rejects_empty() {
        assert!(Manifest::parse(r#"{"chunk_n":1,"tail_n":1,"reduce_ks":[],"entries":[]}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
