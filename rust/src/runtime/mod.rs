//! PJRT runtime — loads the AOT-compiled HLO artifacts (built once by
//! `make artifacts`; python never runs at request time) and exposes the
//! fan-in-k reducer to the data plane.
//!
//! * [`artifacts`] — manifest parsing, HLO-text loading, compilation on
//!   the PJRT CPU client (see /opt/xla-example/load_hlo for the pattern).
//! * [`reducer`] — the k-ary segment-sum entry point: decomposes an
//!   arbitrary fan-in/length onto the compiled (k, n) variants with
//!   zero-padding, with a pure-rust scalar path as fallback and oracle.

pub mod artifacts;
pub mod reducer;

#[cfg(feature = "pjrt")]
pub use artifacts::Artifacts;
pub use artifacts::Manifest;
pub use reducer::{Reducer, ReducerSpec};
