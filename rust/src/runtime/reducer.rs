//! The data-plane k-ary reducer.
//!
//! Decomposes an arbitrary (fan-in, length) segment sum onto the AOT
//! (k, n) variants:
//!
//! * fan-in: padded up with zero rows to the smallest compiled k ≥ fan-in;
//!   fan-ins above the largest compiled k reduce in a tree of max-k
//!   passes (rare in practice — GenTree keeps fan-ins near `w_t`);
//! * length: full `chunk_n` blocks through the big variant, the remainder
//!   through `tail_n` blocks (zero-padded at the very end).
//!
//! `Reducer::Scalar` is the pure-rust path: the correctness oracle, the
//! fallback when artifacts are absent, and the baseline the §Perf pass
//! compares the PJRT path against.

#[cfg(feature = "pjrt")]
use std::sync::Arc;

use anyhow::Result;

#[cfg(feature = "pjrt")]
use super::artifacts::Artifacts;

/// Thread-safe recipe for building a [`Reducer`]. The PJRT client is
/// `Rc`-based (not `Send`), so threads that need a reducer receive a spec
/// and build their own client-local instance — PJRT client-per-thread is
/// the standard affinity model anyway.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReducerSpec {
    Scalar,
    /// PJRT from the default artifact dir, scalar fallback if missing.
    Auto,
    /// PJRT from an explicit artifact dir (hard error if missing).
    PjrtDir(std::path::PathBuf),
}

impl ReducerSpec {
    pub fn build(&self) -> Result<Reducer> {
        match self {
            ReducerSpec::Scalar => Ok(Reducer::Scalar),
            ReducerSpec::Auto => Ok(Reducer::auto()),
            #[cfg(feature = "pjrt")]
            ReducerSpec::PjrtDir(d) => Ok(Reducer::Pjrt(Arc::new(Artifacts::load(d)?))),
            #[cfg(not(feature = "pjrt"))]
            ReducerSpec::PjrtDir(d) => Err(anyhow::anyhow!(
                "built without the `pjrt` feature: cannot load PJRT artifacts from {}",
                d.display()
            )),
        }
    }
}

#[derive(Clone)]
pub enum Reducer {
    /// PJRT-compiled fused kernels (the production path).
    #[cfg(feature = "pjrt")]
    Pjrt(Arc<Artifacts>),
    /// Pure-rust scalar loops (oracle / fallback).
    Scalar,
}

impl Reducer {
    /// Load the PJRT reducer from the default artifact dir, falling back
    /// to scalar when artifacts are missing (e.g. unit tests) or the
    /// `pjrt` feature is off.
    pub fn auto() -> Reducer {
        #[cfg(feature = "pjrt")]
        {
            match Artifacts::load_default() {
                Ok(a) => Reducer::Pjrt(Arc::new(a)),
                Err(_) => Reducer::Scalar,
            }
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Reducer::Scalar
        }
    }

    pub fn is_pjrt(&self) -> bool {
        #[cfg(feature = "pjrt")]
        {
            matches!(self, Reducer::Pjrt(_))
        }
        #[cfg(not(feature = "pjrt"))]
        {
            false
        }
    }

    /// Sum `k` equal-length buffers element-wise.
    pub fn reduce(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        assert!(!inputs.is_empty());
        let len = inputs[0].len();
        for x in inputs {
            assert_eq!(x.len(), len, "ragged reduce inputs");
        }
        if inputs.len() == 1 {
            return Ok(inputs[0].to_vec());
        }
        match self {
            Reducer::Scalar => Ok(scalar_reduce(inputs)),
            #[cfg(feature = "pjrt")]
            Reducer::Pjrt(arts) => pjrt_reduce(arts, inputs),
        }
    }

    /// Fused optimizer step: w − lr·g (PJRT sgd artifact; scalar fallback).
    pub fn sgd_update(&self, w: &mut [f32], g: &[f32], lr: f32) -> Result<()> {
        assert_eq!(w.len(), g.len());
        match self {
            Reducer::Scalar => {
                for (wi, gi) in w.iter_mut().zip(g) {
                    *wi -= lr * gi;
                }
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Reducer::Pjrt(arts) => {
                let n = arts.manifest.chunk_n;
                let len = w.len();
                let mut off = 0;
                while off + n <= len {
                    let out = arts.run_sgd(n, &w[off..off + n], &g[off..off + n], lr)?;
                    w[off..off + n].copy_from_slice(&out);
                    off += n;
                }
                // Scalar tail (cheap relative to a padded dispatch).
                for i in off..len {
                    w[i] -= lr * g[i];
                }
                Ok(())
            }
        }
    }
}

/// Pure-rust fused k-ary sum (single pass over inputs, like the kernel).
pub fn scalar_reduce(inputs: &[&[f32]]) -> Vec<f32> {
    let len = inputs[0].len();
    let mut out = inputs[0].to_vec();
    for x in &inputs[1..] {
        for (o, v) in out.iter_mut().zip(x.iter()) {
            *o += v;
        }
    }
    let _ = len;
    out
}

/// Chained pairwise variant (the Ring-like 3(k−1)n memory pattern) — used
/// by the Fig. 4 bench to measure the δ effect on real hardware.
pub fn scalar_reduce_chained(inputs: &[&[f32]]) -> Vec<f32> {
    let mut acc = inputs[0].to_vec();
    for x in &inputs[1..] {
        // Deliberately materialize a fresh vector per step: read acc,
        // read x, write new — 3 memory streams per add, as a step-by-step
        // algorithm with separate receive buffers would do.
        let next: Vec<f32> = acc.iter().zip(x.iter()).map(|(a, b)| a + b).collect();
        acc = next;
    }
    acc
}

#[cfg(feature = "pjrt")]
fn pjrt_reduce(arts: &Artifacts, inputs: &[&[f32]]) -> Result<Vec<f32>> {
    // Available (k, n) reduce variants, derived from the manifest.
    let mut ns: Vec<usize> = arts
        .manifest
        .entries
        .keys()
        .filter(|(kind, _, _)| kind == "reduce")
        .map(|&(_, _, n)| n)
        .collect();
    ns.sort_unstable();
    ns.dedup();
    let ks_for = |n: usize| -> Vec<usize> {
        let mut ks: Vec<usize> = arts
            .manifest
            .entries
            .keys()
            .filter(|(kind, _, kn)| kind == "reduce" && *kn == n)
            .map(|&(_, k, _)| k)
            .collect();
        ks.sort_unstable();
        ks
    };
    let max_k = *arts.manifest.reduce_ks.iter().max().unwrap();
    let k = inputs.len();
    if k > max_k {
        // Tree pass: fold groups of max_k, then recurse.
        let mut partials: Vec<Vec<f32>> = Vec::new();
        for group in inputs.chunks(max_k) {
            partials.push(if group.len() == 1 {
                group[0].to_vec()
            } else {
                pjrt_reduce(arts, group)?
            });
        }
        let refs: Vec<&[f32]> = partials.iter().map(|v| v.as_slice()).collect();
        return pjrt_reduce(arts, &refs);
    }
    let len = inputs[0].len();
    let mut out = vec![0f32; len];
    let mut flat: Vec<f32> = Vec::new();

    let min_n = ns[0];
    let mut off = 0usize;
    while off < len {
        let remaining = len - off;
        // Largest variant that fits; the tail pads up to the smallest.
        let n = ns
            .iter()
            .rev()
            .find(|&&n| n <= remaining)
            .copied()
            .unwrap_or(min_n);
        // Smallest compiled fan-in ≥ k at this n (zero rows pad the rest).
        let ks = ks_for(n);
        let k_pad = ks
            .iter()
            .find(|&&x| x >= k)
            .copied()
            .unwrap_or_else(|| *ks.last().unwrap());
        let take = n.min(remaining);
        // Pack rows (zero rows for fan-in padding, zero tail for length).
        // The buffer is reused across chunks; only dirty regions are
        // re-zeroed (a full memset per 64 MB chunk is measurable).
        let needed = k_pad * n;
        if flat.len() < needed {
            flat.resize(needed, 0.0);
        }
        for (r, input) in inputs.iter().enumerate() {
            let row = &mut flat[r * n..(r + 1) * n];
            row[..take].copy_from_slice(&input[off..off + take]);
            row[take..].fill(0.0);
        }
        for r in k..k_pad {
            flat[r * n..(r + 1) * n].fill(0.0);
        }
        if take == n {
            // Write straight into the output slice (raw path: zero-copy
            // on the result side).
            let (_, out_tail) = out.split_at_mut(off);
            arts.reduce_into("reduce", k_pad, n, &flat[..k_pad * n], &mut out_tail[..n])?;
        } else {
            let res = arts.run_reduce("reduce", k_pad, n, &flat[..k_pad * n])?;
            out[off..off + take].copy_from_slice(&res[..take]);
        }
        off += take;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_rows(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..k).map(|_| rng.f32_vec(n)).collect()
    }

    fn oracle(rows: &[Vec<f32>]) -> Vec<f32> {
        let n = rows[0].len();
        let mut out = vec![0f64; n];
        for r in rows {
            for (o, v) in out.iter_mut().zip(r) {
                *o += *v as f64;
            }
        }
        out.into_iter().map(|x| x as f32).collect()
    }

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn scalar_matches_oracle() {
        for (k, n) in [(2usize, 10usize), (5, 1000), (16, 7)] {
            let rows = rand_rows(k, n, 42 + k as u64);
            let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
            close(&Reducer::Scalar.reduce(&refs).unwrap(), &oracle(&rows));
        }
    }

    #[test]
    fn chained_matches_fused() {
        let rows = rand_rows(6, 513, 7);
        let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        close(&scalar_reduce(&refs), &scalar_reduce_chained(&refs));
    }

    #[test]
    fn single_input_identity() {
        let rows = rand_rows(1, 64, 1);
        let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        assert_eq!(Reducer::Scalar.reduce(&refs).unwrap(), rows[0]);
    }

    #[test]
    fn scalar_sgd() {
        let mut w = vec![1.0f32, 2.0, 3.0];
        Reducer::Scalar.sgd_update(&mut w, &[1.0, 1.0, 1.0], 0.5).unwrap();
        assert_eq!(w, vec![0.5, 1.5, 2.5]);
    }

    // PJRT-dependent tests live in rust/tests/runtime_roundtrip.rs (they
    // need `make artifacts` to have run).
}
