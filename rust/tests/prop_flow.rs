//! Property tests for the simulator's core: max-min fair rate allocation
//! ([`genmodel::sim::flow::max_min_rates`]). The campaign subsystem
//! treats the simulator as ground truth for algorithm selection, so its
//! allocator invariants are pinned down here:
//!
//! 1. rates are non-negative and never NaN;
//! 2. no link carries more than its (incast-degraded) capacity;
//! 3. the allocation is work-conserving: every finite-rate flow is
//!    bottlenecked by some saturated link on its path;
//! 4. max-min fairness: on that saturated link the flow's rate is
//!    maximal among the link's flows (you cannot raise any flow without
//!    lowering an equal-or-smaller one).

use std::collections::HashMap;

use genmodel::sim::flow::{max_min_rates, Flow, LinkCap};
use genmodel::topo::{Dir, LinkId};
use genmodel::util::prop;
use genmodel::util::rng::Rng;

struct Case {
    flows: Vec<Flow>,
    caps: HashMap<LinkId, LinkCap>,
}

fn link(n: usize) -> LinkId {
    LinkId {
        node: n,
        dir: if n % 2 == 0 { Dir::Up } else { Dir::Down },
    }
}

/// Random allocation problem: up to 10 capped links, up to 16 flows with
/// 1–3 distinct links per path, βs spread over three orders of
/// magnitude, incast thresholds low enough that the ε penalty triggers.
fn random_case(rng: &mut Rng) -> Case {
    let n_links = rng.gen_range(1, 10);
    let mut caps = HashMap::new();
    for i in 0..n_links {
        caps.insert(
            link(i),
            LinkCap {
                beta: 1e-9 * 10f64.powi(rng.gen_range(0, 3) as i32),
                epsilon: if rng.gen_range(0, 2) == 0 { 0.0 } else { 1e-10 },
                w_t: rng.gen_range(2, 12),
            },
        );
    }
    let n_flows = rng.gen_range(1, 16);
    let mut flows = Vec::with_capacity(n_flows);
    for f in 0..n_flows {
        let hops = rng.gen_range(1, 3.min(n_links));
        let mut ids: Vec<usize> = (0..n_links).collect();
        rng.shuffle(&mut ids);
        ids.truncate(hops);
        ids.sort_unstable(); // paths hold distinct links; order is irrelevant to the allocator
        flows.push(Flow {
            src: f,
            dst: f + 1,
            volume: 1.0 + rng.next_f64() * 1e6,
            path: ids.into_iter().map(link).collect(),
        });
    }
    Case { flows, caps }
}

/// Per-link capacity under this allocation round's concurrency, exactly
/// as the allocator computes it.
fn capacities(case: &Case, active: &[usize]) -> HashMap<LinkId, (f64, Vec<usize>)> {
    let mut on_link: HashMap<LinkId, Vec<usize>> = HashMap::new();
    for (ai, &fi) in active.iter().enumerate() {
        for l in &case.flows[fi].path {
            on_link.entry(*l).or_default().push(ai);
        }
    }
    on_link
        .into_iter()
        .map(|(l, ais)| {
            let cap = case.caps[&l].capacity(ais.len());
            (l, (cap, ais))
        })
        .collect()
}

#[test]
fn prop_rates_are_sane_and_capacity_respected() {
    prop::run("flow-capacity", 96, |rng| {
        let case = random_case(rng);
        let active: Vec<usize> = (0..case.flows.len()).collect();
        let rates = max_min_rates(&case.flows, &active, &case.caps);
        if rates.len() != active.len() {
            return Err(format!("rate count {} != active {}", rates.len(), active.len()));
        }
        for (ai, &r) in rates.iter().enumerate() {
            if r.is_nan() || r < 0.0 {
                return Err(format!("flow {ai}: bad rate {r}"));
            }
            // Every generated path crosses a capped link → finite rate.
            if !r.is_finite() {
                return Err(format!("flow {ai}: infinite rate on a capped path"));
            }
        }
        for (l, (cap, ais)) in capacities(&case, &active) {
            let used: f64 = ais.iter().map(|&ai| rates[ai]).sum();
            if used > cap * (1.0 + 1e-6) {
                return Err(format!(
                    "link {l:?} over capacity: used {used:.6e} vs cap {cap:.6e}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allocation_is_work_conserving_and_max_min_fair() {
    prop::run("flow-max-min", 96, |rng| {
        let case = random_case(rng);
        let active: Vec<usize> = (0..case.flows.len()).collect();
        let rates = max_min_rates(&case.flows, &active, &case.caps);
        let link_state = capacities(&case, &active);
        for (ai, &r) in rates.iter().enumerate() {
            // Work conservation: some link on the flow's path must be
            // saturated — otherwise the flow could unilaterally go
            // faster. Max-min fairness: among those saturated links there
            // must be one where this flow's rate is maximal — raising it
            // there would require lowering an equal-or-smaller flow.
            let mut any_saturated = false;
            let mut is_bottlenecked = false;
            for l in &case.flows[active[ai]].path {
                let (cap, ais) = &link_state[l];
                let used: f64 = ais.iter().map(|&a| rates[a]).sum();
                if used < cap * (1.0 - 1e-6) {
                    continue;
                }
                any_saturated = true;
                let max_on_link = ais.iter().map(|&a| rates[a]).fold(0.0f64, f64::max);
                if r >= max_on_link * (1.0 - 1e-6) {
                    is_bottlenecked = true;
                    break;
                }
            }
            if !any_saturated {
                return Err(format!(
                    "flow {ai} (rate {r:.6e}) has no saturated link on its path — \
                     allocation is not work-conserving"
                ));
            }
            if !is_bottlenecked {
                return Err(format!(
                    "flow {ai}: rate {r:.6e} is not maximal on any saturated link of \
                     its path — not max-min fair"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_incast_monotonicity() {
    // More concurrent flows on a link never increases its capacity, and
    // the penalty only starts past the threshold.
    prop::run("flow-incast-monotone", 64, |rng| {
        let cap = LinkCap {
            beta: 1e-9 * (1.0 + rng.next_f64()),
            epsilon: 1e-10 * rng.next_f64(),
            w_t: rng.gen_range(2, 16),
        };
        let mut prev = f64::INFINITY;
        for n_flows in 0..64 {
            let c = cap.capacity(n_flows);
            if !(c > 0.0) || c > prev {
                return Err(format!(
                    "capacity not monotone: {c} after {prev} at {n_flows} flows"
                ));
            }
            if n_flows + 1 <= cap.w_t && (c - 1.0 / cap.beta).abs() > 1e-9 / cap.beta {
                return Err(format!("penalty below threshold at {n_flows} flows"));
            }
            prev = c;
        }
        Ok(())
    });
}
