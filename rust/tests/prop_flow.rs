//! Property tests for the simulator's core: max-min fair rate allocation
//! ([`genmodel::sim::flow::max_min_rates`]), plus the fabric link sets
//! that feed it. The campaign subsystem treats the simulator as ground
//! truth for algorithm selection, so its allocator invariants are pinned
//! down here:
//!
//! 1. rates are non-negative and never NaN;
//! 2. no link carries more than its (incast-degraded) capacity;
//! 3. the allocation is work-conserving: every finite-rate flow is
//!    bottlenecked by some saturated link on its path;
//! 4. max-min fairness: on that saturated link the flow's rate is
//!    maximal among the link's flows (you cannot raise any flow without
//!    lowering an equal-or-smaller one).
//!
//! The mesh/torus tests pin the [`MeshFabric`] link enumeration itself
//! (pairing, cardinality, fan-in) and its dimension-ordered routing, and
//! re-run the allocator invariants over flows on real grid link sets.

use std::collections::{HashMap, HashSet};

use genmodel::sim::flow::{max_min_rates, Flow, LinkCap};
use genmodel::topo::{LinkId, MeshFabric};
use genmodel::util::prop;
use genmodel::util::rng::Rng;

struct Case {
    flows: Vec<Flow>,
    caps: HashMap<LinkId, LinkCap>,
}

/// A synthetic capped link: distinct `n` → distinct directed link.
fn link(n: usize) -> LinkId {
    LinkId { from: n, to: n + 1 }
}

/// Random allocation problem: up to 10 capped links, up to 16 flows with
/// 1–3 distinct links per path, βs spread over three orders of
/// magnitude, incast thresholds low enough that the ε penalty triggers.
fn random_case(rng: &mut Rng) -> Case {
    let n_links = rng.gen_range(1, 10);
    let mut caps = HashMap::new();
    for i in 0..n_links {
        caps.insert(
            link(i),
            LinkCap {
                beta: 1e-9 * 10f64.powi(rng.gen_range(0, 3) as i32),
                epsilon: if rng.gen_range(0, 2) == 0 { 0.0 } else { 1e-10 },
                w_t: rng.gen_range(2, 12),
            },
        );
    }
    let n_flows = rng.gen_range(1, 16);
    let mut flows = Vec::with_capacity(n_flows);
    for f in 0..n_flows {
        let hops = rng.gen_range(1, 3.min(n_links));
        let mut ids: Vec<usize> = (0..n_links).collect();
        rng.shuffle(&mut ids);
        ids.truncate(hops);
        ids.sort_unstable(); // paths hold distinct links; order is irrelevant to the allocator
        flows.push(Flow {
            src: f,
            dst: f + 1,
            volume: 1.0 + rng.next_f64() * 1e6,
            path: ids.into_iter().map(link).collect(),
        });
    }
    Case { flows, caps }
}

/// Per-link capacity under this allocation round's concurrency, exactly
/// as the allocator computes it.
fn capacities(case: &Case, active: &[usize]) -> HashMap<LinkId, (f64, Vec<usize>)> {
    let mut on_link: HashMap<LinkId, Vec<usize>> = HashMap::new();
    for (ai, &fi) in active.iter().enumerate() {
        for l in &case.flows[fi].path {
            on_link.entry(*l).or_default().push(ai);
        }
    }
    on_link
        .into_iter()
        .map(|(l, ais)| {
            let cap = case.caps[&l].capacity(ais.len());
            (l, (cap, ais))
        })
        .collect()
}

#[test]
fn prop_rates_are_sane_and_capacity_respected() {
    prop::run("flow-capacity", 96, |rng| {
        let case = random_case(rng);
        let active: Vec<usize> = (0..case.flows.len()).collect();
        let rates = max_min_rates(&case.flows, &active, &case.caps);
        if rates.len() != active.len() {
            return Err(format!("rate count {} != active {}", rates.len(), active.len()));
        }
        for (ai, &r) in rates.iter().enumerate() {
            if r.is_nan() || r < 0.0 {
                return Err(format!("flow {ai}: bad rate {r}"));
            }
            // Every generated path crosses a capped link → finite rate.
            if !r.is_finite() {
                return Err(format!("flow {ai}: infinite rate on a capped path"));
            }
        }
        for (l, (cap, ais)) in capacities(&case, &active) {
            let used: f64 = ais.iter().map(|&ai| rates[ai]).sum();
            if used > cap * (1.0 + 1e-6) {
                return Err(format!(
                    "link {l:?} over capacity: used {used:.6e} vs cap {cap:.6e}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allocation_is_work_conserving_and_max_min_fair() {
    prop::run("flow-max-min", 96, |rng| {
        let case = random_case(rng);
        let active: Vec<usize> = (0..case.flows.len()).collect();
        let rates = max_min_rates(&case.flows, &active, &case.caps);
        let link_state = capacities(&case, &active);
        for (ai, &r) in rates.iter().enumerate() {
            // Work conservation: some link on the flow's path must be
            // saturated — otherwise the flow could unilaterally go
            // faster. Max-min fairness: among those saturated links there
            // must be one where this flow's rate is maximal — raising it
            // there would require lowering an equal-or-smaller flow.
            let mut any_saturated = false;
            let mut is_bottlenecked = false;
            for l in &case.flows[active[ai]].path {
                let (cap, ais) = &link_state[l];
                let used: f64 = ais.iter().map(|&a| rates[a]).sum();
                if used < cap * (1.0 - 1e-6) {
                    continue;
                }
                any_saturated = true;
                let max_on_link = ais.iter().map(|&a| rates[a]).fold(0.0f64, f64::max);
                if r >= max_on_link * (1.0 - 1e-6) {
                    is_bottlenecked = true;
                    break;
                }
            }
            if !any_saturated {
                return Err(format!(
                    "flow {ai} (rate {r:.6e}) has no saturated link on its path — \
                     allocation is not work-conserving"
                ));
            }
            if !is_bottlenecked {
                return Err(format!(
                    "flow {ai}: rate {r:.6e} is not maximal on any saturated link of \
                     its path — not max-min fair"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_incast_monotonicity() {
    // More concurrent flows on a link never increases its capacity, and
    // the penalty only starts past the threshold.
    prop::run("flow-incast-monotone", 64, |rng| {
        let cap = LinkCap {
            beta: 1e-9 * (1.0 + rng.next_f64()),
            epsilon: 1e-10 * rng.next_f64(),
            w_t: rng.gen_range(2, 16),
        };
        let mut prev = f64::INFINITY;
        for n_flows in 0..64 {
            let c = cap.capacity(n_flows);
            if !(c > 0.0) || c > prev {
                return Err(format!(
                    "capacity not monotone: {c} after {prev} at {n_flows} flows"
                ));
            }
            if n_flows + 1 <= cap.w_t && (c - 1.0 / cap.beta).abs() > 1e-9 / cap.beta {
                return Err(format!("penalty below threshold at {n_flows} flows"));
            }
            prev = c;
        }
        Ok(())
    });
}

/// Random grid: 2–5 rows × 2–5 cols, mesh or torus.
fn random_mesh(rng: &mut Rng) -> MeshFabric {
    let rows = rng.gen_range(2, 5);
    let cols = rng.gen_range(2, 5);
    let wrap = rng.gen_range(0, 1) == 1;
    MeshFabric::new(rows, cols, wrap).expect("2..=5 dims are valid")
}

/// Hop count a dimension-ordered walk takes along one dimension
/// (wrap links only exist at extent ≥ 3 — at 2 they'd duplicate the
/// direct cable).
fn dim_dist(from: usize, to: usize, len: usize, wrap: bool) -> usize {
    let direct = from.abs_diff(to);
    if wrap && len >= 3 {
        direct.min(len - direct)
    } else {
        direct
    }
}

#[test]
fn prop_mesh_torus_link_sets_are_paired_and_complete() {
    prop::run("mesh-link-sets", 64, |rng| {
        let m = random_mesh(rng);
        let links = m.all_links();
        let set: HashSet<LinkId> = links.iter().copied().collect();
        if set.len() != links.len() {
            return Err(format!("{}: duplicate links in all_links()", m.name()));
        }
        // Cardinality: per row, 2·(cols−1) directed links, +2 wrap links
        // when the dimension wraps (extent ≥ 3); columns symmetric.
        let row_dir = if m.wraps() && m.cols() >= 3 {
            2 * m.cols()
        } else {
            2 * (m.cols() - 1)
        };
        let col_dir = if m.wraps() && m.rows() >= 3 {
            2 * m.rows()
        } else {
            2 * (m.rows() - 1)
        };
        let expected = m.rows() * row_dir + m.cols() * col_dir;
        if links.len() != expected {
            return Err(format!(
                "{}: {} directed links, expected {expected}",
                m.name(),
                links.len()
            ));
        }
        for l in &links {
            // Full duplex: every directed link's reverse also exists.
            if !set.contains(&LinkId { from: l.to, to: l.from }) {
                return Err(format!("{}: link {l:?} has no reverse", m.name()));
            }
            // Physical adjacency: one grid hop (possibly a wrap hop).
            let (fr, fc) = m.row_col(l.from);
            let (tr, tc) = m.row_col(l.to);
            let hop = dim_dist(fr, tr, m.rows(), m.wraps())
                + dim_dist(fc, tc, m.cols(), m.wraps());
            if hop != 1 {
                return Err(format!("{}: link {l:?} spans {hop} hops", m.name()));
            }
        }
        // Fan-in matches the inbound directed-link count at every node.
        for &id in m.servers() {
            let inbound = links.iter().filter(|l| l.to == id).count();
            if m.fan_in(id) != inbound {
                return Err(format!(
                    "{}: node {id} fan_in {} but {inbound} inbound links",
                    m.name(),
                    m.fan_in(id)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mesh_paths_chain_over_physical_links() {
    prop::run("mesh-paths", 64, |rng| {
        let m = random_mesh(rng);
        let set: HashSet<LinkId> = m.all_links().into_iter().collect();
        for _ in 0..8 {
            let a = rng.gen_range(0, m.n_servers() - 1);
            let b = rng.gen_range(0, m.n_servers() - 1);
            let path = m.path_links(a, b);
            let (ra, ca) = m.row_col(a);
            let (rb, cb) = m.row_col(b);
            let expected = dim_dist(ra, rb, m.rows(), m.wraps())
                + dim_dist(ca, cb, m.cols(), m.wraps());
            if path.len() != expected {
                return Err(format!(
                    "{}: path {a}→{b} has {} hops, expected {expected}",
                    m.name(),
                    path.len()
                ));
            }
            let mut cur = a;
            for l in &path {
                if l.from != cur {
                    return Err(format!(
                        "{}: path {a}→{b} breaks at {l:?} (expected from {cur})",
                        m.name()
                    ));
                }
                if !set.contains(l) {
                    return Err(format!(
                        "{}: path {a}→{b} uses non-physical link {l:?}",
                        m.name()
                    ));
                }
                cur = l.to;
            }
            if cur != b {
                return Err(format!("{}: path {a}→{b} ends at {cur}", m.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mesh_flows_respect_allocator_invariants() {
    // The allocator invariants (capacity, work conservation, max-min
    // fairness) re-checked over a real grid link set: random server
    // pairs, each flow on its dimension-ordered route, every wafer link
    // capped with an incast-prone LinkCap.
    prop::run("mesh-flow-fairness", 48, |rng| {
        let m = random_mesh(rng);
        let caps: HashMap<LinkId, LinkCap> = m
            .all_links()
            .into_iter()
            .map(|l| {
                (
                    l,
                    LinkCap {
                        beta: 6.4e-9 * (1.0 + rng.next_f64()),
                        epsilon: 6.0e-10,
                        w_t: rng.gen_range(2, 5),
                    },
                )
            })
            .collect();
        let n_flows = rng.gen_range(2, 14);
        let mut flows = Vec::with_capacity(n_flows);
        while flows.len() < n_flows {
            let a = rng.gen_range(0, m.n_servers() - 1);
            let b = rng.gen_range(0, m.n_servers() - 1);
            if a == b {
                continue;
            }
            flows.push(Flow {
                src: a,
                dst: b,
                volume: 1.0 + rng.next_f64() * 1e6,
                path: m.path_links(a, b),
            });
        }
        let case = Case { flows, caps };
        let active: Vec<usize> = (0..case.flows.len()).collect();
        let rates = max_min_rates(&case.flows, &active, &case.caps);
        let link_state = capacities(&case, &active);
        for (ai, &r) in rates.iter().enumerate() {
            if !r.is_finite() || r < 0.0 {
                return Err(format!("{}: flow {ai} bad rate {r}", m.name()));
            }
        }
        for (l, (cap, ais)) in &link_state {
            let used: f64 = ais.iter().map(|&ai| rates[ai]).sum();
            if used > cap * (1.0 + 1e-6) {
                return Err(format!(
                    "{}: link {l:?} over capacity ({used:.6e} > {cap:.6e})",
                    m.name()
                ));
            }
        }
        for (ai, &r) in rates.iter().enumerate() {
            let mut bottlenecked = false;
            for l in &case.flows[ai].path {
                let (cap, ais) = &link_state[l];
                let used: f64 = ais.iter().map(|&a| rates[a]).sum();
                if used < cap * (1.0 - 1e-6) {
                    continue;
                }
                let max_on_link = ais.iter().map(|&a| rates[a]).fold(0.0f64, f64::max);
                if r >= max_on_link * (1.0 - 1e-6) {
                    bottlenecked = true;
                    break;
                }
            }
            if !bottlenecked {
                return Err(format!(
                    "{}: flow {ai} (rate {r:.6e}) not bottlenecked on any \
                     saturated link of its grid route",
                    m.name()
                ));
            }
        }
        Ok(())
    });
}
