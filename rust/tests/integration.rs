//! Cross-module integration tests: the paper's headline claims checked
//! end-to-end across model + plans + gentree + sim (+ executor).

use genmodel::bench;
use genmodel::exec;
use genmodel::gentree;
use genmodel::model::cost::{CostModel, ModelKind};
use genmodel::model::fit::{fit, BenchRow};
use genmodel::model::params::{Environment, ModelParams};
use genmodel::plan::validate::{validate, Goal};
use genmodel::plan::{cps, hcps, rhd, ring};
use genmodel::runtime::Reducer;
use genmodel::sim::{simulate_plan, SimConfig};
use genmodel::topo::builders::*;
use genmodel::util::rng::Rng;

/// Headline accuracy claim (§5.1): GenModel within a few % of "actual"
/// (flow sim), classic model errs >10% somewhere, and GenModel's error is
/// never worse.
#[test]
fn genmodel_accuracy_claim() {
    let env = Environment::paper();
    let mut worst_gen: f64 = 0.0;
    let mut worst_classic: f64 = 0.0;
    for n in [12usize, 15] {
        let topo = single_switch(n);
        let mut plans = vec![cps::allreduce(n), ring::allreduce(n)];
        for fs in gentree::template::ordered_factorizations(n, 8) {
            if fs.len() == 2 {
                plans.push(hcps::allreduce(&fs));
            }
        }
        for p in &plans {
            let actual = simulate_plan(p, 1e8, &topo, &env, &SimConfig::new(&topo)).total;
            let g = CostModel::new(&topo, &env, ModelKind::GenModel).plan_total(p, 1e8);
            let c = CostModel::new(&topo, &env, ModelKind::Classic).plan_total(p, 1e8);
            worst_gen = worst_gen.max((g - actual).abs() / actual);
            worst_classic = worst_classic.max((c - actual).abs() / actual);
        }
    }
    assert!(worst_gen < 0.05, "GenModel worst error {worst_gen:.3}");
    assert!(worst_classic > 0.10, "classic worst error {worst_classic:.3}");
}

/// Theorem 2 across the whole plan zoo: nothing is both δ- and ε-optimal
/// once N > w_t.
#[test]
fn impossibility_theorem_over_plan_zoo() {
    use genmodel::model::optimality::check_impossibility;
    for n in 10..=16usize {
        let mut plans = vec![
            cps::allreduce(n),
            ring::allreduce(n),
            rhd::allreduce(n),
            genmodel::plan::reduce_broadcast::allreduce(n),
        ];
        for fs in gentree::template::ordered_factorizations(n, 16) {
            plans.push(hcps::allreduce(&fs));
        }
        for p in plans {
            let stats = validate(&p, Goal::AllReduce).unwrap();
            check_impossibility(&p, &stats, 9).unwrap();
        }
    }
}

/// GenTree beats every baseline in simulation on every paper topology at
/// every paper size (Table 7's qualitative content, small-to-mid scale).
#[test]
fn gentree_dominates_baselines() {
    let env = Environment::paper();
    for topo in [
        single_switch(24),
        single_switch(32),
        symmetric(4, 24),
        asymmetric(&[32, 32], &[16, 16]),
        cross_dc(&[32, 32], &[16, 16]),
    ] {
        let cfg = SimConfig::new(&topo);
        let n = topo.n_servers();
        for s in [1e7, 1e8] {
            let ours = {
                let out = gentree::generate(&topo, &env, s);
                validate(&out.plan, Goal::AllReduce).unwrap();
                simulate_plan(&out.plan, s, &topo, &env, &cfg).total
            };
            for base in bench::workloads::baselines(n) {
                let theirs = simulate_plan(&base, s, &topo, &env, &cfg).total;
                assert!(
                    ours <= theirs * 1.02,
                    "{} S={s:.0e}: GenTree {ours:.3} vs {} {theirs:.3}",
                    topo.name,
                    base.name
                );
            }
        }
    }
}

/// Fit toolkit round-trip: simulate benches → fit → predictions match.
#[test]
fn fit_roundtrip_through_simulator() {
    let env = Environment::paper();
    let mut rows = Vec::new();
    for n in 2..=15usize {
        for s in [2e7, 1e8] {
            let topo = single_switch(n);
            let t = simulate_plan(&cps::allreduce(n), s, &topo, &env, &SimConfig::new(&topo)).total;
            rows.push(BenchRow { n, s, time: t });
        }
    }
    let f = fit(&rows).unwrap();
    assert_eq!(f.w_t, ModelParams::cpu_testbed().w_t);
    // Predictions reproduce the simulated benches within 5%.
    for r in &rows {
        let pred = f.predict_cps(r.n, r.s);
        assert!(
            (pred - r.time).abs() / r.time < 0.05,
            "n={} S={:.0e}: pred {pred} vs sim {}",
            r.n,
            r.s,
            r.time
        );
    }
}

/// The full pipeline: GenTree plan → validator → simulator → real
/// execution with numeric verification, on a hierarchical topology.
#[test]
fn full_pipeline_hierarchical() {
    let env = Environment::paper();
    let topo = asymmetric(&[4, 4], &[3]);
    let out = gentree::generate(&topo, &env, 1e6);
    validate(&out.plan, Goal::AllReduce).unwrap();
    let sim = simulate_plan(&out.plan, 1e6, &topo, &env, &SimConfig::new(&topo));
    assert!(sim.total > 0.0);
    let mut rng = Rng::new(99);
    let inputs: Vec<Vec<f32>> = (0..topo.n_servers()).map(|_| rng.f32_vec(50_000)).collect();
    let outc = exec::execute_plan(&out.plan, &inputs, &Reducer::Scalar).unwrap();
    exec::verify(&outc, &inputs, 1e-4).unwrap();
}

/// Mirror symmetry: for every baseline, RS validates as ReduceScatter and
/// RS + mirror validates as AllReduce (the §4.2 symmetry GenTree relies on).
#[test]
fn reduce_scatter_mirror_symmetry() {
    for n in [4usize, 7, 8, 12] {
        for rs in [
            cps::reduce_scatter(n),
            ring::reduce_scatter(n),
            rhd::reduce_scatter(n),
        ] {
            validate(&rs, Goal::ReduceScatter).unwrap();
            validate(&rs.into_allreduce(), Goal::AllReduce).unwrap();
        }
    }
    for fs in [vec![2usize, 2], vec![4, 3], vec![2, 3, 2]] {
        let rs = hcps::reduce_scatter(&fs);
        validate(&rs, Goal::ReduceScatter).unwrap();
        validate(&rs.into_allreduce(), Goal::AllReduce).unwrap();
    }
}

/// GPU-pod scenario (Table 4's shape): GenTree beats flat Ring, and the
/// gap narrows as machines increase (inter-machine traffic share grows).
#[test]
fn gpu_pod_speedup_shrinks_with_scale() {
    let env = Environment::gpu();
    let mut speedups = Vec::new();
    for machines in [2usize, 4, 8] {
        let topo = gpu_pod(machines, 8);
        let cfg = SimConfig::new(&topo);
        let s = 3.2e8;
        let gen = {
            let out = gentree::generate(&topo, &env, s);
            simulate_plan(&out.plan, s, &topo, &env, &cfg).total
        };
        let nccl = simulate_plan(
            &ring::allreduce(topo.n_servers()),
            s,
            &topo,
            &env,
            &cfg,
        )
        .total;
        assert!(gen < nccl, "machines={machines}: {gen} !< {nccl}");
        speedups.push(nccl / gen);
    }
    assert!(
        speedups[0] > speedups[2],
        "speedup should shrink with scale: {speedups:?}"
    );
}
