//! Telemetry & calibration end-to-end: the serving loop measures itself,
//! the scorer exposes a mis-parameterized model, and the calibrator's
//! refit re-routes traffic to the algorithm that genuinely wins under
//! the true parameters — campaign → serve → measure → refit → reselect.
//!
//! Also pins the telemetry artifact's on-disk schema byte-for-byte
//! against `rust/tests/fixtures/telemetry_smoke.json` (mirroring the
//! selection-table golden in `campaign.rs`), so the format `repro
//! score`/`repro calibrate` consume cannot drift silently.

use std::sync::Arc;
use std::time::Duration;

use genmodel::api::{AlgoSpec, Engine};
use genmodel::bench::workloads::parse_topology;
use genmodel::campaign::table_from_model;
use genmodel::coordinator::{
    AllReduceService, BatchPolicy, ObserveMode, PlanRouter, ServiceConfig,
};
use genmodel::model::params::{Environment, ModelParams};
use genmodel::runtime::ReducerSpec;
use genmodel::telemetry::{self, Recorder, TelemetrySnapshot};
use genmodel::topo::builders::single_switch;
use genmodel::util::rng::Rng;

fn tensors(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f32_vec(len)).collect()
}

// ---- golden file: the telemetry on-disk schema --------------------------

#[test]
fn telemetry_snapshot_golden_file_roundtrip() {
    // Deterministic observations: seconds chosen so the nanosecond
    // rounding is exact and every derived field is an integer.
    let rec = Recorder::new();
    rec.record("single:8", 8, 16, "cps", 65_536, 0.002);
    rec.record("single:8", 8, 16, "cps", 65_536, 0.002);
    rec.record("single:8", 8, 20, "ring", 1_048_576, 0.016);
    let snap = rec.snapshot();

    let golden = include_str!("fixtures/telemetry_smoke.json");
    let path = std::env::temp_dir().join(format!(
        "genmodel_telemetry_golden_{}.json",
        std::process::id()
    ));
    snap.save(&path).unwrap();
    let written = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        written, golden,
        "telemetry snapshot schema drifted from \
         rust/tests/fixtures/telemetry_smoke.json — if the schema change \
         is intentional, bump telemetry::SCHEMA and regenerate the fixture"
    );
    // And the pinned bytes parse back to the identical snapshot.
    let back = TelemetrySnapshot::load(&path).unwrap();
    assert_eq!(back, snap);
    let _ = std::fs::remove_file(&path);
}

// ---- the calibration loop, end to end -----------------------------------

/// The "true" fabric the service actually runs on: the paper's CPU
/// testbed parameters with a 20× incast slope — a congested fabric whose
/// ε term dominates at high fan-in, as §3.2 measures.
fn true_params() -> ModelParams {
    let p = ModelParams::cpu_testbed();
    ModelParams {
        epsilon: p.epsilon * 20.0,
        ..p
    }
}

/// The deliberately mis-parameterized model the stale selection table
/// was built from: blind to the paper's two new terms (δ = ε = 0) — the
/// classic (α, β, γ) worldview.
fn stale_params() -> ModelParams {
    ModelParams {
        delta: 0.0,
        epsilon: 0.0,
        ..ModelParams::cpu_testbed()
    }
}

/// Serve a deterministic CPS workload through sim-backed coordinators on
/// six worker counts, all feeding one shared recorder — the distinct-`n`
/// spread the §3.4 fit needs, recorded under the campaign's class keys.
fn serve_workload(recorder: &Arc<Recorder>) {
    for n in [4usize, 6, 8, 10, 12, 15] {
        let cfg = ServiceConfig {
            policy: BatchPolicy::with_cap(1), // every job its own batch
            flush_after: Duration::from_millis(1),
            algo: AlgoSpec::Cps,
            observe: ObserveMode::Sim, // deterministic observed seconds
            ..ServiceConfig::default()
        }
        .with_telemetry(recorder.clone(), &format!("single:{n}"));
        let svc = AllReduceService::start(
            single_switch(n),
            Environment::uniform(true_params()),
            ReducerSpec::Scalar,
            cfg,
        );
        for (i, &len) in [65_536usize, 1 << 20].iter().enumerate() {
            let res = svc
                .allreduce(tensors(n, len, (n * 10 + i) as u64))
                .unwrap();
            assert_eq!(res.algo, "cps");
            assert!(res.observed_secs > 0.0);
        }
        svc.stop();
    }
}

#[test]
fn score_detects_drift_and_calibration_reroutes_the_incast_bucket() {
    let recorder = Arc::new(Recorder::new());
    serve_workload(&recorder);
    let snap = recorder.snapshot();
    // The lifecycle decomposition records three `stage:*` sentinel cells
    // alongside every batch cell; `CellKey::is_stage` keeps them out of
    // everything the scoring/calibration loop below consumes.
    let batch_cells: Vec<_> = snap.cells.iter().filter(|(k, _)| !k.is_stage()).collect();
    assert_eq!(batch_cells.len(), 12, "6 classes × 2 buckets: {snap:?}");
    assert_eq!(
        snap.cells.len(),
        12 * 4,
        "each batch cell carries its 3 stage sentinels"
    );
    for (_, cell) in &batch_cells {
        assert_eq!(cell.batches(), 1);
    }

    // The stale table: winners derived under the blind parameters over
    // exactly the served grid. The classic model's verdict is CPS
    // everywhere (fewest rounds, optimal bandwidth).
    let grid = snap.buckets_by_class();
    let algos = [
        AlgoSpec::Cps,
        AlgoSpec::Hcps { factors: vec![5, 3] },
        AlgoSpec::Ring,
    ];
    let stale_env = Environment::uniform(stale_params());
    let stale = table_from_model(&grid, &algos, &stale_env).unwrap();
    let stale_choice = stale.lookup("single:15", 1 << 20).unwrap().clone();
    assert_eq!(stale_choice.algo, "cps", "the blind model routes cps");

    // 1. The Scorer detects the mispredicted cells: observed (sim under
    // the congested fabric) vs predicted (blind model). The incast-heavy
    // big-n big-bucket cell is the worst offender by far; the
    // incast-free small-n cells score close.
    let scored = telemetry::score_cells(
        &snap,
        &[] as &[genmodel::campaign::CampaignRow],
        |class, bucket, algo| {
            let topo = parse_topology(class).ok()?;
            let spec = AlgoSpec::parse(algo).ok()?;
            Engine::new(topo, stale_env.clone())
                .predict_bucket(&spec, bucket)
                .ok()
        },
    );
    let summary = telemetry::summarize(&scored);
    assert_eq!(summary.matched, 12, "every cell got a prediction");
    assert!(
        summary.max_abs_rel_err > 0.5,
        "the blind model must mispredict the congested fabric badly, \
         got max |rel err| {:.3}",
        summary.max_abs_rel_err
    );
    assert!(
        summary.worst.as_deref().unwrap().contains("single:15"),
        "the worst offender is the highest-fan-in class: {:?}",
        summary.worst
    );
    // score_cells orders worst-first and the incast-free 4-server rack
    // scores far better than the 15-server one.
    assert_eq!(scored[0].key.class, "single:15");
    let small = scored
        .iter()
        .find(|c| c.key.class == "single:4" && c.key.bucket == 16)
        .unwrap();
    assert!(
        small.rel_err().unwrap().abs() < 0.3,
        "incast-free cell should score close: {:?}",
        small.rel_err()
    );

    // 2. The Calibrator refits from the served (n, s, time) samples: the
    // recovered ε must see the congestion the stale model is blind to.
    let cal = telemetry::calibrate(&snap, true_params().beta).unwrap();
    assert_eq!(cal.rows_used, 12);
    assert!(
        cal.params.epsilon > true_params().epsilon * 0.3,
        "refit missed the incast slope: ε̂ = {:.3e} vs true {:.3e}",
        cal.params.epsilon,
        true_params().epsilon
    );
    assert!(
        cal.params.alpha > 0.0 && cal.fitted.two_beta_plus_gamma > 0.0,
        "{:?}",
        cal.fitted
    );

    // 3. The recalibrated table re-routes the incast-heavy bucket to the
    // hierarchical plan — a *different* winner than the stale table's...
    let recal = telemetry::recalibrated_table(&snap, &cal, &algos).unwrap();
    let recal_choice = recal.lookup("single:15", 1 << 20).unwrap().clone();
    assert_ne!(
        recal_choice.algo, stale_choice.algo,
        "recalibration must change the routed winner for the incast bucket"
    );
    assert_eq!(recal_choice.algo, "hcps:5x3", "{recal:?}");
    // ...that is genuinely cheaper under the true parameters.
    let truth = Engine::new(single_switch(15), Environment::uniform(true_params()));
    let new_s = truth
        .predict_bucket(&AlgoSpec::parse(&recal_choice.algo).unwrap(), 20)
        .unwrap();
    let old_s = truth
        .predict_bucket(&AlgoSpec::parse(&stale_choice.algo).unwrap(), 20)
        .unwrap();
    assert!(
        new_s < old_s,
        "recalibrated winner must beat the stale one under the true \
         params: {new_s} vs {old_s}"
    );
    // Where the true params do NOT flip the winner (incast-free small
    // bucket: CPS's two rounds still win), the refit leaves routing
    // alone — calibration is surgical, not a blanket reroute.
    assert_eq!(recal.lookup("single:15", 65_536).unwrap().algo, "cps");
    assert_eq!(recal.lookup("single:4", 1 << 20).unwrap().algo, "cps");
}

// ---- telemetry keys join the serving path's own bucketing ---------------

#[test]
fn recorded_buckets_match_router_buckets() {
    let recorder = Arc::new(Recorder::new());
    let svc = AllReduceService::start(
        single_switch(4),
        Environment::paper(),
        ReducerSpec::Scalar,
        ServiceConfig {
            policy: BatchPolicy::with_cap(1),
            flush_after: Duration::from_millis(1),
            algo: AlgoSpec::Ring,
            ..ServiceConfig::default()
        }
        .with_telemetry(recorder.clone(), "single:4"),
    );
    svc.allreduce(tensors(4, 3000, 1)).unwrap();
    svc.stop();
    let snap = recorder.snapshot();
    let batch_keys: Vec<_> = snap.cells.keys().filter(|k| !k.is_stage()).collect();
    assert_eq!(batch_keys.len(), 1, "{snap:?}");
    let key = batch_keys[0];
    assert_eq!(key.bucket, PlanRouter::bucket(3000));
    assert_eq!(key.algo, "ring");
    assert_eq!(key.class, "single:4");
}
