//! Fleet serving end-to-end: one rack's drift fixes another rack's
//! table. Rack A (`single:15`) serves incast-heavy traffic under a
//! blind δ=ε=0 table on an ε×20 congested fabric and trips its budget;
//! rack B (`single:12`) serves only incast-free traffic under an
//! equally stale table and never trips its own budget — yet after A's
//! trip drives the pooled §3.4 refit, B's table is pushed too, and B's
//! big-bucket winner (which B never exercised) is verifiably cheaper
//! under the true parameters than the blind choice it replaced. Honest
//! racks hold (no epoch churn), every result is numerically verified
//! against the oracle, and no job is dropped across the pushes.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use genmodel::api::{AlgoSpec, Engine};
use genmodel::campaign::table_from_model;
use genmodel::coordinator::{BatchPolicy, JobResult, ObserveMode, DEFAULT_LINK_BETA};
use genmodel::fleet::{default_candidates, FleetController, FleetReport, FleetSpec};
use genmodel::model::params::{Environment, ModelParams};
use genmodel::runtime::ReducerSpec;
use genmodel::topo::builders::single_switch;
use genmodel::util::rng::Rng;

const BIG: usize = 1 << 20; // bucket 20: incast-dominated on the congested fabric
const SMALL: usize = 65_536; // bucket 16: incast-free, stays honest

fn tensors(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f32_vec(len)).collect()
}

/// The "true" fabric: the paper's CPU testbed with a 20× incast slope.
fn true_params() -> ModelParams {
    let p = ModelParams::cpu_testbed();
    ModelParams {
        epsilon: p.epsilon * 20.0,
        ..p
    }
}

/// The classic (α,β,γ) worldview the stale racks' tables were priced
/// under.
fn stale_params() -> ModelParams {
    ModelParams {
        delta: 0.0,
        epsilon: 0.0,
        ..ModelParams::cpu_testbed()
    }
}

fn spec(class: &str, buckets: &[u32], pricing: ModelParams, threshold: f64) -> FleetSpec {
    let topo = genmodel::bench::workloads::parse_topology(class).unwrap();
    let grid: BTreeMap<String, BTreeSet<u32>> =
        BTreeMap::from([(class.to_string(), buckets.iter().copied().collect())]);
    let table = table_from_model(
        &grid,
        &default_candidates(&topo),
        &Environment::uniform(pricing),
    )
    .unwrap();
    FleetSpec {
        class: class.to_string(),
        threshold,
        table,
        env: Environment::uniform(true_params()), // the fabric reality
        candidates: Vec::new(),
        policy: BatchPolicy::with_cap(1), // every job its own batch
        flush_after: Duration::from_millis(1),
        observe: ObserveMode::Sim, // deterministic observed seconds
        reducer: ReducerSpec::Scalar,
        min_split_margin: 1.25,
        ingest_lanes: 0,
        slo: None,
    }
}

/// Submit one verified job: the result must match the exact oracle sum.
fn serve_one(fleet: &FleetController, class: &str, len: usize, seed: u64) -> JobResult {
    let entry = fleet.entry(class).unwrap();
    let ts = tensors(entry.n_workers, len, seed);
    let want = genmodel::exec::oracle_sum(&ts);
    let res = entry.service.allreduce(ts).unwrap();
    for (a, b) in res.reduced.iter().zip(&want) {
        assert!(
            (a - b).abs() < 1e-3 * b.abs().max(1.0),
            "{class}: {a} vs {b}"
        );
    }
    res
}

#[test]
fn one_racks_drift_recalibrates_every_racks_table() {
    let mut fleet = FleetController::new(DEFAULT_LINK_BETA);
    // Rack A: stale table over its one served bucket — the tripwire.
    fleet
        .register(spec("single:15", &[20], stale_params(), 0.5))
        .unwrap();
    // Rack B: equally stale table over BOTH buckets, but it only ever
    // serves the incast-free one — its own traffic can't expose the lie.
    fleet
        .register(spec("single:12", &[16, 20], stale_params(), 0.5))
        .unwrap();
    // Honest racks: truth-priced; their cps traffic at four more worker
    // counts is what gives the pooled fit its multi-n spread.
    for n in [4usize, 6, 8, 10] {
        fleet
            .register(spec(&format!("single:{n}"), &[16], true_params(), 0.5))
            .unwrap();
    }
    // Sanity: the blind model routes cps in the incast bucket on both
    // stale racks.
    for class in ["single:15", "single:12"] {
        let view = fleet.entry(class).unwrap().handle.view();
        assert_eq!(view.table.lookup(class, BIG).unwrap().algo, "cps");
    }

    // Wave 1 — every rack serves real verified traffic at epoch 0.
    for (i, seed) in (0..2u64).enumerate() {
        let res = serve_one(&fleet, "single:15", BIG, seed);
        assert_eq!((res.algo.as_str(), res.epoch), ("cps", 0), "A job {i}");
        let res = serve_one(&fleet, "single:12", SMALL, 10 + seed);
        assert_eq!((res.algo.as_str(), res.epoch), ("cps", 0), "B job {i}");
    }
    for n in [4usize, 6, 8, 10] {
        let res = serve_one(&fleet, &format!("single:{n}"), SMALL, 20 + n as u64);
        assert_eq!((res.algo.as_str(), res.epoch), ("cps", 0));
    }

    // The fleet check: only A trips, the pooled snapshot spans six
    // worker counts of cps-served cells, so the §3.4 fit fires — and the
    // fitted environment re-prices BOTH stale racks while the honest
    // racks' routing survives the refit untouched.
    let check = fleet.check();
    let tripped: Vec<&str> = check.tripped().map(|c| c.class.as_str()).collect();
    assert_eq!(tripped, ["single:15"], "{check:?}");
    assert!(check.fitted, "pooled fit must fire: {check:?}");
    assert!(check.failed.is_empty(), "{check:?}");
    assert_eq!(check.pushed, ["single:12", "single:15"], "{check:?}");
    assert_eq!(
        check.held,
        ["single:10", "single:4", "single:6", "single:8"],
        "{check:?}"
    );
    assert_eq!(fleet.monitor().trips_for("single:15"), 1);
    assert_eq!(
        fleet.monitor().trips_for("single:12"),
        0,
        "B never tripped its own budget — the push was cross-rack"
    );

    // B's pushed table: the big bucket it never served now routes a
    // winner that is genuinely cheaper under the true parameters than
    // the blind cps choice — while its served small bucket keeps cps
    // (the merge is surgical).
    let b = fleet.entry("single:12").unwrap();
    assert_eq!(b.handle.epoch(), 1);
    let b_view = b.handle.view();
    let b_choice = b_view.table.lookup("single:12", BIG).unwrap().clone();
    assert_ne!(b_choice.algo, "cps", "{b_choice:?}");
    let truth = Engine::new(single_switch(12), Environment::uniform(true_params()));
    let new_s = truth
        .predict_bucket(&AlgoSpec::parse(&b_choice.algo).unwrap(), 20)
        .unwrap();
    let old_s = truth.predict_bucket(&AlgoSpec::Cps, 20).unwrap();
    assert!(
        new_s < old_s,
        "the cross-rack push must improve B under the true params: \
         {} at {new_s} vs cps at {old_s}",
        b_choice.algo
    );
    assert_eq!(b_view.table.lookup("single:12", SMALL).unwrap().algo, "cps");
    for n in [4usize, 6, 8, 10] {
        let e = fleet.entry(&format!("single:{n}")).unwrap();
        assert_eq!(e.handle.epoch(), 0, "honest racks' epochs are not churned");
    }

    // Wave 2 — the pushed racks' leaders observe the new epoch on their
    // very next served jobs; A routes the recalibrated winner; nothing
    // fails and the honest racks keep serving at epoch 0.
    let res = serve_one(&fleet, "single:15", BIG, 40);
    assert_eq!(res.epoch, 1, "A's leader observed the swap");
    assert_ne!(res.algo, "cps", "A routes the recalibrated winner");
    let res = serve_one(&fleet, "single:12", SMALL, 41);
    assert_eq!(res.epoch, 1, "B's leader observed the cross-rack push");
    assert_eq!(res.algo, "cps", "B's served bucket kept its winner");
    for n in [4usize, 6, 8, 10] {
        let res = serve_one(&fleet, &format!("single:{n}"), SMALL, 50 + n as u64);
        assert_eq!(res.epoch, 0);
    }
    let check2 = fleet.check();
    assert!(check2.failed.is_empty(), "{check2:?}");
    assert!(
        !check2.tripped().any(|c| c.class != "single:15"),
        "only A's fit-residual cell may ever re-trip: {check2:?}"
    );

    fleet.stop();
    let report = FleetReport::collect(&fleet);
    assert_eq!(report.dropped_jobs(), 0, "no job dropped across the pushes");
    assert_eq!(report.stats.failures, 0);
    assert!(report.stats.calibrator_fits >= 1);
    assert!(report.stats.holds >= 4, "{:?}", report.stats);
    // A's swap stranded its cached blind plan; the leader evicted it.
    let a_metrics = fleet.entry("single:15").unwrap().service.metrics.snapshot();
    assert!(a_metrics.drift_evictions >= 1, "{a_metrics:?}");
    let text = report.render();
    assert!(text.contains("single:12") && text.contains("0 dropped job(s)"), "{text}");
}
