//! Coordinator end-to-end: the service over the real PJRT data plane.

use std::sync::Arc;
use std::time::Duration;

use genmodel::coordinator::{batcher::BatchPolicy, AllReduceService, ServiceConfig};
use genmodel::exec;
use genmodel::model::params::Environment;
use genmodel::runtime::ReducerSpec;
use genmodel::topo::builders::{asymmetric, single_switch};
use genmodel::util::rng::Rng;

fn cfg(bucket: usize) -> ServiceConfig {
    ServiceConfig {
        policy: BatchPolicy {
            bucket_floats: bucket,
        },
        flush_after: Duration::from_millis(1),
        ..ServiceConfig::default()
    }
}

fn tensors(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f32_vec(len)).collect()
}

fn check(result: &[f32], inputs: &[Vec<f32>]) {
    let want = exec::oracle_sum(&inputs.to_vec());
    assert_eq!(result.len(), want.len());
    for (a, b) in result.iter().zip(&want) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
    }
}

#[test]
fn pjrt_service_correct_results() {
    let svc = AllReduceService::start(
        single_switch(8),
        Environment::paper(),
        ReducerSpec::Auto, // PJRT when artifacts built, scalar otherwise
        cfg(1 << 22),
    );
    for seed in 0..4 {
        let ts = tensors(8, 70_000, seed); // spans chunk + tail kernels
        let want = ts.clone();
        let res = svc.allreduce(ts).unwrap();
        check(&res.reduced, &want);
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.jobs_completed, 4);
}

#[test]
fn burst_of_concurrent_clients() {
    let svc = Arc::new(AllReduceService::start(
        single_switch(6),
        Environment::paper(),
        ReducerSpec::Auto,
        cfg(1 << 22),
    ));
    let mut handles = Vec::new();
    for seed in 0..16u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let ts = tensors(6, 2000 + (seed as usize) * 13, seed);
            let want = ts.clone();
            let res = svc.allreduce(ts).unwrap();
            check(&res.reduced, &want);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.jobs_completed, 16);
    // Bucketing must have fused at least some of the burst.
    assert!(
        m.batches_flushed < 16,
        "no fusion happened: {} batches",
        m.batches_flushed
    );
}

#[test]
fn hierarchical_topology_service() {
    let svc = AllReduceService::start(
        asymmetric(&[3, 3], &[2]),
        Environment::paper(),
        ReducerSpec::Auto,
        cfg(1 << 20),
    );
    let ts = tensors(8, 10_000, 42);
    let want = ts.clone();
    let res = svc.allreduce(ts).unwrap();
    check(&res.reduced, &want);
    assert!(res.plan_name.contains("GenTree"));
}

#[test]
fn training_like_loop_through_service() {
    // 50 "steps" of gradient sync; deterministic convergence of a toy
    // quadratic: every worker pulls a shared parameter toward zero.
    let n = 4;
    let svc = AllReduceService::start(
        single_switch(n),
        Environment::paper(),
        ReducerSpec::Auto,
        ServiceConfig::default(),
    );
    let dim = 512;
    let mut rng = Rng::new(5);
    let mut w: Vec<f32> = rng.f32_vec(dim);
    for _ in 0..50 {
        // grad_i = (w + noise_i); averaged grad ≈ w.
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                w.iter()
                    .map(|x| x + rng.next_f32_signed() * 0.01)
                    .collect()
            })
            .collect();
        let sum = svc.allreduce(grads).unwrap().reduced;
        for (wi, g) in w.iter_mut().zip(&sum) {
            *wi -= 0.1 * (g / n as f32);
        }
    }
    let norm: f32 = w.iter().map(|x| x * x).sum::<f32>() / dim as f32;
    assert!(norm < 1e-3, "did not converge: {norm}");
}
