//! Coordinator end-to-end: the service over the real PJRT data plane,
//! including the campaign selection table driving BOTH the router (which
//! algorithm serves each batch) and the batcher (where a fuse must stop
//! so the routed algorithm still wins).

use std::sync::Arc;
use std::time::Duration;

use genmodel::campaign::{table_from_choices, Metric, SelectionTable};
use genmodel::coordinator::{
    AllReduceService, BatchPolicy, BatchRule, PlanRouter, ServiceConfig,
};
use genmodel::exec;
use genmodel::model::params::Environment;
use genmodel::runtime::ReducerSpec;
use genmodel::topo::builders::{asymmetric, single_switch};
use genmodel::util::rng::Rng;

fn cfg(bucket: usize) -> ServiceConfig {
    ServiceConfig {
        policy: BatchPolicy::with_cap(bucket),
        flush_after: Duration::from_millis(1),
        ..ServiceConfig::default()
    }
}

fn tensors(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f32_vec(len)).collect()
}

fn check(result: &[f32], inputs: &[Vec<f32>]) {
    let want = exec::oracle_sum(&inputs.to_vec());
    assert_eq!(result.len(), want.len());
    for (a, b) in result.iter().zip(&want) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
    }
}

#[test]
fn pjrt_service_correct_results() {
    let svc = AllReduceService::start(
        single_switch(8),
        Environment::paper(),
        ReducerSpec::Auto, // PJRT when artifacts built, scalar otherwise
        cfg(1 << 22),
    );
    for seed in 0..4 {
        let ts = tensors(8, 70_000, seed); // spans chunk + tail kernels
        let want = ts.clone();
        let res = svc.allreduce(ts).unwrap();
        check(&res.reduced, &want);
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.jobs_completed, 4);
}

#[test]
fn burst_of_concurrent_clients() {
    let svc = Arc::new(AllReduceService::start(
        single_switch(6),
        Environment::paper(),
        ReducerSpec::Auto,
        cfg(1 << 22),
    ));
    let mut handles = Vec::new();
    for seed in 0..16u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let ts = tensors(6, 2000 + (seed as usize) * 13, seed);
            let want = ts.clone();
            let res = svc.allreduce(ts).unwrap();
            check(&res.reduced, &want);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.jobs_completed, 16);
    // Bucketing must have fused at least some of the burst.
    assert!(
        m.batches_flushed < 16,
        "no fusion happened: {} batches",
        m.batches_flushed
    );
}

#[test]
fn hierarchical_topology_service() {
    let svc = AllReduceService::start(
        asymmetric(&[3, 3], &[2]),
        Environment::paper(),
        ReducerSpec::Auto,
        cfg(1 << 20),
    );
    let ts = tensors(8, 10_000, 42);
    let want = ts.clone();
    let res = svc.allreduce(ts).unwrap();
    check(&res.reduced, &want);
    assert!(res.plan_name.contains("GenTree"));
}

// ---- selection-aware batching, end to end ------------------------------

/// Two-cell table for an 8-server rack: `ring` wins the small buckets,
/// `rhd` wins from bucket 17 (> 65536 floats) up. `margin` is the small
/// (departed) cell's winner/runner-up ratio — the number the batcher
/// weighs against `min_split_margin` at the boundary. The same table is
/// pinned byte-for-byte by the golden-file test in `campaign.rs`.
fn two_cell_table(margin: f64) -> SelectionTable {
    table_from_choices(
        Metric::Model,
        &[
            ("single:8", 10, "ring", 1.0, margin),
            ("single:8", 17, "rhd", 1.0, 2.0),
        ],
    )
}

/// Service wired to `two_cell_table(margin)` with a flush window wide
/// enough (1 s against a burst submitted in microseconds) that one burst
/// of sequential submissions lands in a single batch-planning cycle even
/// on a heavily loaded CI machine.
fn selection_service(margin: f64) -> AllReduceService {
    let cfg = ServiceConfig {
        policy: BatchPolicy::with_cap(1 << 22),
        flush_after: Duration::from_secs(1),
        ..ServiceConfig::default()
    }
    .with_selection_table(&two_cell_table(margin), "single:8", 1.25)
    .unwrap();
    AllReduceService::start(single_switch(8), Environment::paper(), ReducerSpec::Scalar, cfg)
}

/// A burst straddling the bucket-17 boundary: two 1000-float jobs (which
/// fuse to 2000) and one 100_000-float job. Returns the three results in
/// submission order.
fn straddling_burst(svc: &AllReduceService) -> Vec<genmodel::coordinator::JobResult> {
    let mut pending = Vec::new();
    let mut wants = Vec::new();
    for (len, seed) in [(1000usize, 1u64), (1000, 2), (100_000, 3)] {
        let ts = tensors(8, len, seed);
        wants.push(ts.clone());
        pending.push(svc.submit(ts).unwrap());
    }
    let results: Vec<_> = pending
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap())
        .collect();
    for (res, want) in results.iter().zip(&wants) {
        check(&res.reduced, want);
    }
    results
}

#[test]
fn decisive_margin_splits_the_fuse_and_every_job_routes_its_winner() {
    // A 3.0x margin at the boundary clears min_split_margin = 1.25: the
    // batcher must stop the fuse at 2000 floats instead of dragging the
    // small jobs into the rhd bucket.
    let table = two_cell_table(3.0);
    let svc = selection_service(3.0);
    let results = straddling_burst(&svc);
    // Each JobResult.algo is exactly the table's winner for the batch
    // the job actually rode in — small pair on ring, large job on rhd.
    assert_eq!(results[0].algo, table.lookup("single:8", 2000).unwrap().algo);
    assert_eq!(results[0].algo, "ring");
    assert_eq!(results[1].algo, "ring");
    assert_eq!(results[2].algo, table.lookup("single:8", 100_000).unwrap().algo);
    assert_eq!(results[2].algo, "rhd");
    // The split is visible in the reported rule: the small pair's batch
    // closed at the boundary, inside its claimed bucket, at the table's
    // margin.
    assert_eq!(results[0].batch_jobs, 2, "burst did not fuse in one cycle");
    match results[0].rule {
        BatchRule::SplitAtBucket { bucket, margin } => {
            assert_eq!(bucket, PlanRouter::bucket(2000));
            assert!((margin - 3.0).abs() < 1e-9, "margin {margin}");
        }
        other => panic!("expected SplitAtBucket, got {other:?}"),
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.batches_split_at_bucket, 1);
    assert_eq!(m.jobs_completed, 3);
}

#[test]
fn weak_margin_fuses_through_like_the_cap_only_policy() {
    // The same burst under a 1.05x boundary: not worth breaking the
    // fuse, so all three jobs ride one batch — which crosses into the
    // rhd bucket, exactly what the cap-only policy would have done.
    let svc = selection_service(1.05);
    let results = straddling_burst(&svc);
    for res in &results {
        assert_eq!(res.batch_jobs, 3, "burst did not fuse in one cycle");
        assert_eq!(res.algo, "rhd", "fused batch must route the big bucket's winner");
        assert_eq!(res.rule, BatchRule::Drained);
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.batches_split_at_bucket, 0, "no boundary was decisive");
    assert_eq!(m.batches_flushed, 1);
}

#[test]
fn training_like_loop_through_service() {
    // 50 "steps" of gradient sync; deterministic convergence of a toy
    // quadratic: every worker pulls a shared parameter toward zero.
    let n = 4;
    let svc = AllReduceService::start(
        single_switch(n),
        Environment::paper(),
        ReducerSpec::Auto,
        ServiceConfig::default(),
    );
    let dim = 512;
    let mut rng = Rng::new(5);
    let mut w: Vec<f32> = rng.f32_vec(dim);
    for _ in 0..50 {
        // grad_i = (w + noise_i); averaged grad ≈ w.
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                w.iter()
                    .map(|x| x + rng.next_f32_signed() * 0.01)
                    .collect()
            })
            .collect();
        let sum = svc.allreduce(grads).unwrap().reduced;
        for (wi, g) in w.iter_mut().zip(&sum) {
            *wi -= 0.1 * (g / n as f32);
        }
    }
    let norm: f32 = w.iter().map(|x| x * x).sum::<f32>() / dim as f32;
    assert!(norm < 1e-3, "did not converge: {norm}");
}
