//! Campaign subsystem integration tests: artifact determinism across
//! worker counts, resume-from-partial-JSONL, and the selection table
//! demonstrably driving the coordinator's routing.

use std::fs;
use std::path::PathBuf;

use genmodel::api::AlgoSpec;
use genmodel::campaign::{
    load_rows, run_campaign, table_from_choices, Metric, RunConfig, ScenarioGrid, SelectionTable,
};
use genmodel::coordinator::{AllReduceService, PlanRouter, ServiceConfig};
use genmodel::model::params::Environment;
use genmodel::runtime::ReducerSpec;
use genmodel::bench::workloads::parse_topology;
use genmodel::topo::builders::single_switch;
use genmodel::util::rng::Rng;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("genmodel_campaign_{tag}_{}.jsonl", std::process::id()))
}

/// A grid small enough for CI but wide enough that winners differ by
/// size bucket: two sizes spanning the latency- and bandwidth-dominated
/// regimes, every algorithm applicable on a 6-server rack.
fn test_grid() -> ScenarioGrid {
    ScenarioGrid {
        name: "test".into(),
        topos: vec!["single:4".into(), "single:6".into()],
        sizes: vec![1e3, 1e7],
        algos: Vec::new(),
        env: genmodel::campaign::EnvKind::Paper,
        exec_spot_cap: 0.0,
    }
}

#[test]
fn artifact_is_byte_identical_across_worker_counts() {
    let out1 = tmp("det1");
    let out4 = tmp("det4");
    let _ = fs::remove_file(&out1);
    let _ = fs::remove_file(&out4);
    let grid = test_grid();
    let s1 = run_campaign(&grid, &RunConfig { threads: 1, out: out1.clone() }).unwrap();
    let s4 = run_campaign(&grid, &RunConfig { threads: 4, out: out4.clone() }).unwrap();
    assert_eq!(s1.total, s4.total);
    assert_eq!(s1.failed, 0);
    let b1 = fs::read(&out1).unwrap();
    let b4 = fs::read(&out4).unwrap();
    assert_eq!(b1, b4, "campaign JSONL must not depend on worker count");

    // The derived selection tables are byte-identical too.
    let t1 = SelectionTable::from_rows(&load_rows(&out1).unwrap(), Metric::Model);
    let t4 = SelectionTable::from_rows(&load_rows(&out4).unwrap(), Metric::Model);
    assert_eq!(t1.to_json().to_string(), t4.to_json().to_string());
    assert!(!t1.is_empty());
    let _ = fs::remove_file(&out1);
    let _ = fs::remove_file(&out4);
}

#[test]
fn interrupted_campaign_resumes_and_converges() {
    let full = tmp("resume_full");
    let part = tmp("resume_part");
    let _ = fs::remove_file(&full);
    let _ = fs::remove_file(&part);
    let grid = test_grid();
    run_campaign(&grid, &RunConfig { threads: 2, out: full.clone() }).unwrap();
    let complete = fs::read_to_string(&full).unwrap();
    let lines: Vec<&str> = complete.lines().collect();
    assert!(lines.len() >= 8, "grid too small to test resume: {}", lines.len());

    // Simulate an interruption: keep the first 3 rows plus a torn line.
    let mut partial: String = lines[..3].join("\n");
    partial.push('\n');
    partial.push_str("{\"algo\":\"ring\",\"truncat"); // torn mid-write
    fs::write(&part, &partial).unwrap();

    let resumed = run_campaign(&grid, &RunConfig { threads: 3, out: part.clone() }).unwrap();
    assert_eq!(resumed.resumed, 3, "the 3 intact rows must be memoized");
    assert_eq!(resumed.evaluated, lines.len() - 3);
    assert_eq!(
        fs::read_to_string(&part).unwrap(),
        complete,
        "a resumed campaign must converge to the from-scratch artifact"
    );
    let _ = fs::remove_file(&full);
    let _ = fs::remove_file(&part);
}

#[test]
fn campaign_to_selection_to_service_end_to_end() {
    // The full pipeline of the acceptance criterion: sweep → selection
    // table → AllReduceService routes each job to the table's winner for
    // its size bucket.
    let out = tmp("e2e");
    let _ = fs::remove_file(&out);
    let grid = ScenarioGrid {
        name: "e2e".into(),
        topos: vec!["single:6".into()],
        sizes: vec![1e3, 1e7],
        algos: Vec::new(),
        env: genmodel::campaign::EnvKind::Paper,
        exec_spot_cap: 0.0,
    };
    run_campaign(&grid, &RunConfig { threads: 2, out: out.clone() }).unwrap();
    let table = SelectionTable::from_rows(&load_rows(&out).unwrap(), Metric::Model);
    let rules = table.rules_for("single:6").unwrap();
    assert!(!rules.is_empty());

    let svc = AllReduceService::start(
        single_switch(6),
        Environment::paper(),
        ReducerSpec::Scalar,
        ServiceConfig {
            selection: rules,
            ..ServiceConfig::default()
        },
    );
    let mut rng = Rng::new(9);
    for len in [1_000usize, 50_000] {
        let tensors: Vec<Vec<f32>> = (0..6).map(|_| rng.f32_vec(len)).collect();
        let res = svc.allreduce(tensors).unwrap();
        // The served algorithm is exactly the table's winner for this
        // payload's bucket.
        let want = table
            .lookup("single:6", len)
            .unwrap_or_else(|| panic!("no selection for {len}"));
        assert_eq!(res.algo, want.algo, "job of {len} floats");
    }
    let _ = fs::remove_file(&out);
}

#[test]
fn gpu_smoke_grid_expands_dedupes_and_selects_deterministically() {
    let grid = ScenarioGrid::gpu_smoke();
    let keys: Vec<String> = grid.expand().unwrap().iter().map(|s| s.key()).collect();
    let again: Vec<String> = grid.expand().unwrap().iter().map(|s| s.key()).collect();
    assert_eq!(keys, again, "expansion order is deterministic");
    let unique: std::collections::BTreeSet<&String> = keys.iter().collect();
    assert_eq!(unique.len(), keys.len(), "expansion is deduplicated");

    let out = tmp("gpu_smoke");
    let _ = fs::remove_file(&out);
    let summary = run_campaign(&grid, &RunConfig { threads: 2, out: out.clone() }).unwrap();
    assert_eq!(summary.failed, 0, "gpu-smoke must sweep cleanly");
    let rows = load_rows(&out).unwrap();
    assert_eq!(rows.len(), keys.len());
    // Exactly the spot-check scenarios carry an executed-backend wall
    // time (the real data plane verified them against the oracle).
    assert!(rows.iter().any(|r| r.exec_s.is_some()), "no exec spot-check rows ran");
    for r in &rows {
        assert_eq!(r.exec_s.is_some(), r.key.ends_with("|exec"), "{}", r.key);
    }
    // Selection is deterministic whatever the row order — exec wall
    // times (machine-dependent) never influence the winners.
    let t1 = SelectionTable::from_rows(&rows, Metric::Model);
    let mut reversed = rows.clone();
    reversed.reverse();
    let t2 = SelectionTable::from_rows(&reversed, Metric::Model);
    assert_eq!(t1.to_json().to_string(), t2.to_json().to_string());
    assert!(!t1.is_empty());
    let _ = fs::remove_file(&out);
}

/// The table the coordinator e2e tests serve with, checked byte-for-byte
/// against `rust/tests/fixtures/selection_two_cell.json` so the
/// `SelectionTable` on-disk schema cannot drift silently.
#[test]
fn selection_table_golden_file_roundtrip() {
    let table = table_from_choices(
        Metric::Model,
        &[
            ("single:8", 10, "ring", 1.0, 3.0),
            ("single:8", 17, "rhd", 1.0, 2.0),
        ],
    );
    let golden = include_str!("fixtures/selection_two_cell.json");
    let path = tmp("golden").with_extension("json");
    table.save(&path).unwrap();
    let written = fs::read_to_string(&path).unwrap();
    assert_eq!(
        written, golden,
        "SelectionTable serialization drifted from the checked-in fixture \
         rust/tests/fixtures/selection_two_cell.json — if the schema change \
         is intentional, update the fixture in the same commit"
    );
    // Reloading the fixture reproduces the table, its boundaries, and
    // routing rules that still parse against the registry.
    let loaded = SelectionTable::load(&path).unwrap();
    assert_eq!(loaded, table);
    assert_eq!(loaded.boundaries_for("single:8"), table.boundaries_for("single:8"));
    let rules = loaded.rules_for("single:8").unwrap();
    assert_eq!(rules.len(), 2);
    assert_eq!(rules[&10], AlgoSpec::Ring);
    assert_eq!(rules[&17], AlgoSpec::Rhd);
    let _ = fs::remove_file(&path);
}

/// Same schema pin for a grid-fabric class: the `mesh:4x4` selection
/// table the mesh CI smoke serves with, byte-for-byte against
/// `rust/tests/fixtures/selection_mesh4x4.json`, and its rules parsing
/// back into the fabric-aware registry specs.
#[test]
fn mesh_selection_table_golden_file_roundtrip() {
    let table = table_from_choices(
        Metric::Model,
        &[
            ("mesh:4x4", 13, "cps", 1.0, 3.0),
            ("mesh:4x4", 27, "wafer", 1.0, 2.0),
        ],
    );
    let golden = include_str!("fixtures/selection_mesh4x4.json");
    let path = tmp("mesh_golden").with_extension("json");
    table.save(&path).unwrap();
    let written = fs::read_to_string(&path).unwrap();
    assert_eq!(
        written, golden,
        "SelectionTable serialization drifted from the checked-in fixture \
         rust/tests/fixtures/selection_mesh4x4.json — if the schema change \
         is intentional, update the fixture in the same commit"
    );
    let loaded = SelectionTable::load(&path).unwrap();
    assert_eq!(loaded, table);
    let rules = loaded.rules_for("mesh:4x4").unwrap();
    assert_eq!(rules.len(), 2);
    assert_eq!(rules[&13], AlgoSpec::Cps);
    assert_eq!(rules[&27], AlgoSpec::Wafer);
    let _ = fs::remove_file(&path);
}

/// The tentpole acceptance criterion, end to end at the library layer: a
/// MESH4x4 campaign sweeps every applicable algorithm, the selection
/// table under BOTH metrics (GenModel and the flow simulator) hands the
/// bandwidth-dominated bucket to a fabric-aware algorithm (wafer or
/// genall), and a coordinator serving that table on the mesh routes a
/// live job to the table's winner.
#[test]
fn mesh_campaign_to_selection_to_service_end_to_end() {
    let out = tmp("mesh_e2e");
    let _ = fs::remove_file(&out);
    let grid = ScenarioGrid {
        name: "mesh_e2e".into(),
        topos: vec!["mesh:4x4".into()],
        sizes: vec![1e4, 1.34e8],
        algos: Vec::new(),
        env: genmodel::campaign::EnvKind::Paper,
        exec_spot_cap: 0.0,
    };
    let summary = run_campaign(&grid, &RunConfig { threads: 2, out: out.clone() }).unwrap();
    assert_eq!(summary.failed, 0, "the mesh sweep must price cleanly");
    let rows = load_rows(&out).unwrap();
    assert!(
        rows.iter().any(|r| r.algo == "wafer") && rows.iter().any(|r| r.algo == "genall"),
        "both fabric-aware algorithms must be swept on mesh:4x4"
    );

    for metric in [Metric::Model, Metric::Sim] {
        let table = SelectionTable::from_rows(&rows, metric);
        let winner = table
            .lookup("mesh:4x4", 1.34e8 as usize)
            .unwrap_or_else(|| panic!("no {metric} selection for the 2^27 bucket"));
        let family = AlgoSpec::parse(&winner.algo).unwrap().family();
        assert!(
            matches!(family, "wafer" | "genall"),
            "by {metric}, the bandwidth-dominated bucket must go to a \
             fabric-aware algorithm, got {}",
            winner.algo
        );
    }

    let table = SelectionTable::from_rows(&rows, Metric::Model);
    let rules = table.rules_for("mesh:4x4").unwrap();
    let svc = AllReduceService::start(
        parse_topology("mesh:4x4").unwrap(),
        Environment::paper(),
        ReducerSpec::Scalar,
        ServiceConfig {
            selection: rules,
            ..ServiceConfig::default()
        },
    );
    let mut rng = Rng::new(11);
    let len = 1_000usize;
    let tensors: Vec<Vec<f32>> = (0..16).map(|_| rng.f32_vec(len)).collect();
    let res = svc.allreduce(tensors).unwrap();
    let want = table
        .lookup("mesh:4x4", len)
        .unwrap_or_else(|| panic!("no selection for {len}"));
    assert_eq!(res.algo, want.algo, "mesh job of {len} floats");
    let _ = fs::remove_file(&out);
}

#[test]
fn selection_roundtrips_through_disk_and_feeds_the_router() {
    let out = tmp("disk");
    let table_path = out.with_extension("selection.json");
    let _ = fs::remove_file(&out);
    let grid = ScenarioGrid {
        name: "disk".into(),
        topos: vec!["single:4".into()],
        sizes: vec![1e4],
        algos: vec!["cps".into(), "ring".into(), "gentree".into()],
        env: genmodel::campaign::EnvKind::Paper,
        exec_spot_cap: 0.0,
    };
    run_campaign(&grid, &RunConfig { threads: 2, out: out.clone() }).unwrap();
    let table = SelectionTable::from_rows(&load_rows(&out).unwrap(), Metric::Sim);
    table.save(&table_path).unwrap();
    let loaded = SelectionTable::load(&table_path).unwrap();
    assert_eq!(loaded, table);

    let router = PlanRouter::new(single_switch(4), Environment::paper())
        .with_selection(loaded.rules_for("single:4").unwrap());
    let routed = router.plan_for(1e4 as usize).unwrap();
    assert_eq!(
        routed.algo.to_string(),
        loaded.lookup("single:4", 1e4 as usize).unwrap().algo
    );
    let _ = fs::remove_file(&out);
    let _ = fs::remove_file(&table_path);
}
