//! Campaign subsystem integration tests: artifact determinism across
//! worker counts, resume-from-partial-JSONL, and the selection table
//! demonstrably driving the coordinator's routing.

use std::fs;
use std::path::PathBuf;

use genmodel::campaign::{
    load_rows, run_campaign, Metric, RunConfig, ScenarioGrid, SelectionTable,
};
use genmodel::coordinator::{AllReduceService, PlanRouter, ServiceConfig};
use genmodel::model::params::Environment;
use genmodel::runtime::ReducerSpec;
use genmodel::topo::builders::single_switch;
use genmodel::util::rng::Rng;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("genmodel_campaign_{tag}_{}.jsonl", std::process::id()))
}

/// A grid small enough for CI but wide enough that winners differ by
/// size bucket: two sizes spanning the latency- and bandwidth-dominated
/// regimes, every algorithm applicable on a 6-server rack.
fn test_grid() -> ScenarioGrid {
    ScenarioGrid {
        name: "test".into(),
        topos: vec!["single:4".into(), "single:6".into()],
        sizes: vec![1e3, 1e7],
        algos: Vec::new(),
        env: genmodel::campaign::EnvKind::Paper,
    }
}

#[test]
fn artifact_is_byte_identical_across_worker_counts() {
    let out1 = tmp("det1");
    let out4 = tmp("det4");
    let _ = fs::remove_file(&out1);
    let _ = fs::remove_file(&out4);
    let grid = test_grid();
    let s1 = run_campaign(&grid, &RunConfig { threads: 1, out: out1.clone() }).unwrap();
    let s4 = run_campaign(&grid, &RunConfig { threads: 4, out: out4.clone() }).unwrap();
    assert_eq!(s1.total, s4.total);
    assert_eq!(s1.failed, 0);
    let b1 = fs::read(&out1).unwrap();
    let b4 = fs::read(&out4).unwrap();
    assert_eq!(b1, b4, "campaign JSONL must not depend on worker count");

    // The derived selection tables are byte-identical too.
    let t1 = SelectionTable::from_rows(&load_rows(&out1).unwrap(), Metric::Model);
    let t4 = SelectionTable::from_rows(&load_rows(&out4).unwrap(), Metric::Model);
    assert_eq!(t1.to_json().to_string(), t4.to_json().to_string());
    assert!(!t1.is_empty());
    let _ = fs::remove_file(&out1);
    let _ = fs::remove_file(&out4);
}

#[test]
fn interrupted_campaign_resumes_and_converges() {
    let full = tmp("resume_full");
    let part = tmp("resume_part");
    let _ = fs::remove_file(&full);
    let _ = fs::remove_file(&part);
    let grid = test_grid();
    run_campaign(&grid, &RunConfig { threads: 2, out: full.clone() }).unwrap();
    let complete = fs::read_to_string(&full).unwrap();
    let lines: Vec<&str> = complete.lines().collect();
    assert!(lines.len() >= 8, "grid too small to test resume: {}", lines.len());

    // Simulate an interruption: keep the first 3 rows plus a torn line.
    let mut partial: String = lines[..3].join("\n");
    partial.push('\n');
    partial.push_str("{\"algo\":\"ring\",\"truncat"); // torn mid-write
    fs::write(&part, &partial).unwrap();

    let resumed = run_campaign(&grid, &RunConfig { threads: 3, out: part.clone() }).unwrap();
    assert_eq!(resumed.resumed, 3, "the 3 intact rows must be memoized");
    assert_eq!(resumed.evaluated, lines.len() - 3);
    assert_eq!(
        fs::read_to_string(&part).unwrap(),
        complete,
        "a resumed campaign must converge to the from-scratch artifact"
    );
    let _ = fs::remove_file(&full);
    let _ = fs::remove_file(&part);
}

#[test]
fn campaign_to_selection_to_service_end_to_end() {
    // The full pipeline of the acceptance criterion: sweep → selection
    // table → AllReduceService routes each job to the table's winner for
    // its size bucket.
    let out = tmp("e2e");
    let _ = fs::remove_file(&out);
    let grid = ScenarioGrid {
        name: "e2e".into(),
        topos: vec!["single:6".into()],
        sizes: vec![1e3, 1e7],
        algos: Vec::new(),
        env: genmodel::campaign::EnvKind::Paper,
    };
    run_campaign(&grid, &RunConfig { threads: 2, out: out.clone() }).unwrap();
    let table = SelectionTable::from_rows(&load_rows(&out).unwrap(), Metric::Model);
    let rules = table.rules_for("single:6").unwrap();
    assert!(!rules.is_empty());

    let svc = AllReduceService::start(
        single_switch(6),
        Environment::paper(),
        ReducerSpec::Scalar,
        ServiceConfig {
            selection: rules,
            ..ServiceConfig::default()
        },
    );
    let mut rng = Rng::new(9);
    for len in [1_000usize, 50_000] {
        let tensors: Vec<Vec<f32>> = (0..6).map(|_| rng.f32_vec(len)).collect();
        let res = svc.allreduce(tensors).unwrap();
        // The served algorithm is exactly the table's winner for this
        // payload's bucket.
        let want = table
            .lookup("single:6", len)
            .unwrap_or_else(|| panic!("no selection for {len}"));
        assert_eq!(res.algo, want.algo, "job of {len} floats");
    }
    let _ = fs::remove_file(&out);
}

#[test]
fn selection_roundtrips_through_disk_and_feeds_the_router() {
    let out = tmp("disk");
    let table_path = out.with_extension("selection.json");
    let _ = fs::remove_file(&out);
    let grid = ScenarioGrid {
        name: "disk".into(),
        topos: vec!["single:4".into()],
        sizes: vec![1e4],
        algos: vec!["cps".into(), "ring".into(), "gentree".into()],
        env: genmodel::campaign::EnvKind::Paper,
    };
    run_campaign(&grid, &RunConfig { threads: 2, out: out.clone() }).unwrap();
    let table = SelectionTable::from_rows(&load_rows(&out).unwrap(), Metric::Sim);
    table.save(&table_path).unwrap();
    let loaded = SelectionTable::load(&table_path).unwrap();
    assert_eq!(loaded, table);

    let router = PlanRouter::new(single_switch(4), Environment::paper())
        .with_selection(loaded.rules_for("single:4").unwrap());
    let routed = router.plan_for(1e4 as usize).unwrap();
    assert_eq!(
        routed.algo.to_string(),
        loaded.lookup("single:4", 1e4 as usize).unwrap().algo
    );
    let _ = fs::remove_file(&out);
    let _ = fs::remove_file(&table_path);
}
