//! Property-style stress tests for the sharded ingest front door
//! (`genmodel::coordinator::ingest`) and the service built on it.
//!
//! These are the PR's acceptance claims, stated as tests: N concurrent
//! producers on M lanes lose nothing and duplicate nothing, per-lane
//! FIFO holds, `stop()` under concurrent submit fire drains every
//! accepted job to completion (zero drops), and a poisoned producer
//! lane degrades to typed errors while the rest of the fleet's lanes
//! keep serving.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::time::Duration;

use genmodel::api::ApiError;
use genmodel::coordinator::{
    AllReduceService, BatchPolicy, IngestLanes, IngestWait, ObserveMode, ServiceConfig,
};
use genmodel::model::params::Environment;
use genmodel::runtime::ReducerSpec;
use genmodel::topo::builders::single_switch;

const PRODUCERS: usize = 8;
const PER_PRODUCER: usize = 500;

/// N producers × M lanes with a concurrent drainer: every (producer,
/// seq) pair arrives exactly once, and within each producer's pinned
/// lane the sequence numbers drain strictly increasing (per-lane FIFO).
#[test]
fn concurrent_producers_lose_nothing_duplicate_nothing_keep_lane_fifo() {
    for lanes in [1usize, 3, 8] {
        let ing = IngestLanes::<(usize, usize)>::new(lanes);
        let got = std::thread::scope(|s| {
            let consumer = s.spawn(|| {
                let mut got: Vec<(usize, usize)> = Vec::new();
                let mut buf = Vec::new();
                loop {
                    match ing.wait(None) {
                        IngestWait::Ready => {
                            ing.drain_into(&mut buf);
                            got.append(&mut buf);
                        }
                        IngestWait::Closed => {
                            // Sweep until a pass finds nothing: items
                            // accepted before close must all surface.
                            while ing.drain_into(&mut buf) > 0 {
                                got.append(&mut buf);
                            }
                            return got;
                        }
                        IngestWait::TimedOut => unreachable!("no deadline was set"),
                    }
                }
            });
            let producers: Vec<_> = (0..PRODUCERS)
                .map(|t| {
                    let ing = &ing;
                    s.spawn(move || {
                        for seq in 0..PER_PRODUCER {
                            ing.push_to(t % ing.lane_count(), (t, seq)).expect("open");
                        }
                    })
                })
                .collect();
            for p in producers {
                p.join().expect("producer panicked");
            }
            // Close only after every producer finished, or the consumer
            // would park forever and deadlock the scope join.
            ing.close();
            consumer.join().expect("consumer panicked")
        });
        assert_eq!(got.len(), PRODUCERS * PER_PRODUCER, "{lanes} lanes");
        let unique: HashSet<(usize, usize)> = got.iter().copied().collect();
        assert_eq!(unique.len(), got.len(), "duplicated items at {lanes} lanes");
        for t in 0..PRODUCERS {
            let seqs: Vec<usize> =
                got.iter().filter(|(p, _)| *p == t).map(|(_, s)| *s).collect();
            assert_eq!(seqs.len(), PER_PRODUCER);
            assert!(
                seqs.windows(2).all(|w| w[0] < w[1]),
                "producer {t} drained out of order at {lanes} lanes"
            );
        }
    }
}

/// `stop()` while 8 threads are still submitting: every submit either
/// returns a receiver that completes with a result, or the typed
/// `ServiceStopped` — never a hang, never a dropped accepted job.
#[test]
fn stop_under_concurrent_submit_fire_drains_every_accepted_job() {
    let svc = AllReduceService::start(
        single_switch(4),
        Environment::paper(),
        ReducerSpec::Scalar,
        ServiceConfig {
            policy: BatchPolicy::with_cap(1 << 20),
            flush_after: Duration::from_micros(100),
            observe: ObserveMode::Sim,
            ingest_lanes: 4,
            ..ServiceConfig::default()
        },
    );
    let stop_now = AtomicBool::new(false);
    let (accepted, receivers) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|_| {
                let svc = &svc;
                let stop_now = &stop_now;
                s.spawn(move || {
                    let mut mine: Vec<Receiver<Result<_, ApiError>>> = Vec::new();
                    loop {
                        let tensors: Vec<Vec<f32>> =
                            (0..4).map(|_| vec![1.0f32; 32]).collect();
                        match svc.submit(tensors) {
                            Ok(rx) => mine.push(rx),
                            Err(ApiError::ServiceStopped) => return mine,
                            Err(other) => panic!("unexpected submit error: {other:?}"),
                        }
                        if stop_now.load(Ordering::Relaxed) && mine.len() >= 8 {
                            return mine;
                        }
                    }
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(10));
        stop_now.store(true, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(2));
        svc.stop();
        let mut accepted = 0usize;
        let mut receivers = Vec::new();
        for h in handles {
            let mine = h.join().expect("producer panicked");
            accepted += mine.len();
            receivers.push(mine);
        }
        (accepted, receivers)
    });
    assert!(accepted > 0, "fixture never accepted a job");
    // Zero dropped: every accepted submit completes with an Ok result.
    for rx in receivers.into_iter().flatten() {
        let res = rx
            .recv()
            .expect("accepted job's channel was dropped without a result");
        res.expect("accepted job failed");
    }
    let m = svc.metrics.snapshot();
    assert_eq!(
        m.jobs_completed as usize, accepted,
        "completed ≠ accepted: jobs were dropped or invented"
    );
    assert_eq!(m.jobs_submitted as usize, accepted);
}

/// The lock-poisoning claim at the lanes layer: a producer that panics
/// while holding one lane's lock poisons only that lane — pushes there
/// return the typed `IngestClosed` (which the service maps to
/// `ServiceStopped`), while other lanes keep accepting and the drain
/// still surfaces everything else, in lane order.
#[test]
fn poisoned_lane_is_isolated_from_its_neighbors() {
    let ing = IngestLanes::<u32>::new(4);
    ing.push_to(1, 11).unwrap();
    // Panic while holding lane 2's lock.
    ing.poison_lane(2);
    assert!(ing.push_to(2, 22).is_err(), "poisoned lane must reject");
    ing.push_to(3, 33).unwrap();
    let mut out = Vec::new();
    while ing.drain_into(&mut out) > 0 {}
    assert_eq!(out, vec![11, 33], "healthy lanes drain in lane order");
    assert!(!ing.is_closed(), "a poisoned lane does not close the doors");
}
