//! Flight-recorder end-to-end: the drift_e2e scenario with tracing on.
//!
//! On the ε×20 congested fabric under a blind δ=ε=0 table, the drift
//! swap's trace event must *name the incast term* as the dominant eater
//! of the observed−predicted gap (>50%) — the paper's §2/§3 claim that
//! the classic model's blind spot is exactly the fan-in surcharge. The
//! δ=ε=0 control (fabric and table agree) must trip nothing and leave
//! every executed batch attributed within budget.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use genmodel::api::AlgoSpec;
use genmodel::campaign::table_from_model;
use genmodel::coordinator::{
    AllReduceService, BatchPolicy, DriftConfig, ObserveMode, ServiceConfig,
};
use genmodel::model::params::{Environment, ModelParams};
use genmodel::runtime::ReducerSpec;
use genmodel::topo::builders::single_switch;
use genmodel::trace::{SpanKind, Term, TraceRecorder};
use genmodel::util::rng::Rng;

fn tensors(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f32_vec(len)).collect()
}

/// The "true" fabric: the paper's CPU testbed with a 20× incast slope.
fn true_params() -> ModelParams {
    let p = ModelParams::cpu_testbed();
    ModelParams {
        epsilon: p.epsilon * 20.0,
        ..p
    }
}

/// The classic (α,β,γ) worldview the stale table was priced under.
fn stale_params() -> ModelParams {
    ModelParams {
        delta: 0.0,
        epsilon: 0.0,
        ..ModelParams::cpu_testbed()
    }
}

fn candidates() -> Vec<AlgoSpec> {
    vec![
        AlgoSpec::Cps,
        AlgoSpec::Hcps { factors: vec![5, 3] },
        AlgoSpec::Ring,
    ]
}

fn traced_service(
    table_params: ModelParams,
    fabric: ModelParams,
    trace: &Arc<TraceRecorder>,
) -> AllReduceService {
    const N: usize = 15;
    let grid: BTreeMap<String, BTreeSet<u32>> =
        BTreeMap::from([(format!("single:{N}"), BTreeSet::from([20u32]))]);
    let table =
        table_from_model(&grid, &candidates(), &Environment::uniform(table_params)).unwrap();
    let recorder = Arc::new(genmodel::telemetry::Recorder::new());
    let cfg = ServiceConfig {
        policy: BatchPolicy::with_cap(1),
        flush_after: Duration::from_millis(1),
        observe: ObserveMode::Sim,
        drift: Some(DriftConfig {
            threshold: 0.5,
            every: 4,
            algos: candidates(),
            ..DriftConfig::default()
        }),
        ..ServiceConfig::default()
    }
    .with_selection_table(&table, "single:15", 1.25)
    .unwrap()
    .with_telemetry(recorder, "single:15")
    .with_trace(trace.clone());
    AllReduceService::start(
        single_switch(N),
        Environment::uniform(fabric),
        ReducerSpec::Scalar,
        cfg,
    )
}

#[test]
fn drift_swap_trace_blames_the_incast_term() {
    const N: usize = 15;
    const BIG: usize = 1 << 20;
    let trace = Arc::new(TraceRecorder::new());
    // Blind table, congested reality: the drift_e2e trip, now recorded.
    let svc = traced_service(stale_params(), true_params(), &trace);
    for i in 0..4u64 {
        let res = svc.allreduce(tensors(N, BIG, i)).unwrap();
        assert_eq!(res.algo, "cps");
    }
    // The 4th flush reached the check cadence and swapped; one post-swap
    // job runs under the new winner so the trace sees both generations.
    let res = svc.allreduce(tensors(N, BIG, 9)).unwrap();
    assert_eq!(res.epoch, 1);
    svc.stop();

    let snap = trace.snapshot();
    assert_eq!(snap.dropped, 0, "a short smoke must not lap the ring");

    // The serving lifecycle is fully spanned: one enqueue per job, one
    // flush + one attributed exec per single-job batch, per-phase spans
    // underneath each exec.
    assert_eq!(snap.of_kind(SpanKind::JobEnqueue).count(), 5);
    assert_eq!(snap.of_kind(SpanKind::BatchFlush).count(), 5);
    assert_eq!(snap.attributed_execs(), 5);
    assert!(
        snap.of_kind(SpanKind::Phase).count() >= 2 * 5,
        "every AllReduce round has at least reduce + broadcast phases"
    );
    for e in snap.of_kind(SpanKind::Phase) {
        assert!(e.attribution().is_some(), "phase spans carry attributions");
    }
    assert!(snap.of_kind(SpanKind::DriftCheck).count() >= 1);

    // THE acceptance pin: the swap event attributes the gap, and the
    // dominant term is incast — more than half of the total attributed
    // deviation on a fabric whose only lie was the ε slope.
    let swaps: Vec<_> = snap.of_kind(SpanKind::DriftSwap).collect();
    assert_eq!(swaps.len(), 1, "{swaps:?}");
    let swap = swaps[0];
    assert_eq!(snap.name(swap.span.class), "single:15");
    assert_eq!(snap.name(swap.span.algo), "cps", "the stale winner is blamed");
    assert_eq!(swap.span.epoch, 1);
    let attr = swap.attribution().expect("swap events are attributed");
    assert_eq!(attr.dominant(), Term::Incast, "{attr:?}");
    assert!(
        attr.dominant_share() > 0.5,
        "incast must eat >50% of the attributed gap: {attr:?}"
    );
    assert!(attr.incast_s > 0.0);

    // The service metric agrees with the trace.
    let m = svc.metrics.snapshot();
    assert_eq!(m.drift_term, Term::Incast.code());
    assert_eq!(m.drift_swaps, 1);

    // The artifact roundtrips losslessly.
    let back = genmodel::trace::TraceSnapshot::from_jsonl(&snap.to_jsonl()).unwrap();
    assert_eq!(back.attributed_execs(), 5);
    assert_eq!(back.events.len(), snap.events.len());
}

#[test]
fn honest_control_attributes_within_budget_and_never_trips() {
    const N: usize = 15;
    const BIG: usize = 1 << 20;
    let trace = Arc::new(TraceRecorder::new());
    // Control: the fabric IS the δ=ε=0 worldview and the table was priced
    // under it — predictions are honest, nothing should trip.
    let svc = traced_service(stale_params(), stale_params(), &trace);
    for i in 0..4u64 {
        svc.allreduce(tensors(N, BIG, i)).unwrap();
    }
    svc.stop();

    let snap = trace.snapshot();
    assert_eq!(snap.dropped, 0);
    assert_eq!(snap.of_kind(SpanKind::DriftSwap).count(), 0, "no swap");
    assert!(snap.of_kind(SpanKind::DriftCheck).count() >= 1, "checked, held");
    assert_eq!(svc.metrics.snapshot().drift_swaps, 0);
    assert_eq!(svc.metrics.snapshot().drift_term, 0, "no term ever blamed");

    // Every executed batch is attributed, and the model explains the
    // round: the unexplained remainder stays within the drift budget the
    // monitor holds predictions to (50%), fleet-wide and per span.
    assert_eq!(snap.attributed_execs(), 4);
    assert!(
        snap.unexplained_frac() < 0.5,
        "honest fabric must be mostly explained: {}",
        snap.unexplained_frac()
    );
    for e in snap.of_kind(SpanKind::BatchExec) {
        let attr = e.attribution().unwrap();
        let observed = e.span.dur_ns as f64 * 1e-9;
        assert!(
            attr.unexplained_s.abs() < 0.5 * observed.max(1e-12),
            "span unexplained {:+.3e}s vs observed {observed:.3e}s",
            attr.unexplained_s
        );
    }
}
