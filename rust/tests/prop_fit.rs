//! Property tests for the §3.4 parameter-fitting toolkit
//! (`model/fit.rs`): synthetic benchmark rows generated from *known*
//! `(α, 2β+γ, δ, ε, w_t)` must round-trip through `fit` — the recovered
//! parameters reproduce every row's time, and when the incast threshold
//! is observable the parameters themselves come back, including at the
//! piecewise `w_t` scan's edges (the minimum candidate `w_t = 2` and the
//! "no incast in the data" maximum `w_t = max_n + 1`).

use genmodel::model::expressions::{genmodel, PlanType};
use genmodel::model::fit::{fit, BenchRow, FittedParams};
use genmodel::model::params::ModelParams;
use genmodel::util::prop;
use genmodel::util::rng::Rng;

fn synth_rows(p: &ModelParams, sizes: &[f64], max_n: usize) -> Vec<BenchRow> {
    let mut rows = Vec::new();
    for n in 2..=max_n {
        for &s in sizes {
            rows.push(BenchRow {
                n,
                s,
                time: genmodel(&PlanType::ColocatedPs, n, s, p).total(),
            });
        }
    }
    rows
}

/// Log-uniform draw in `[lo, hi]` — parameters live on decade scales.
fn draw(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    (lo.ln() + rng.next_f64() * (hi.ln() - lo.ln())).exp()
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-30)
}

/// Every row's time must be reproduced by the fitted parameters.
fn check_prediction_roundtrip(f: &FittedParams, rows: &[BenchRow]) -> Result<(), String> {
    for r in rows {
        let pred = f.predict_cps(r.n, r.s);
        if rel(pred, r.time) > 1e-6 {
            return Err(format!(
                "prediction drifted at n={} s={:.2e}: {pred} vs {}",
                r.n, r.s, r.time
            ));
        }
    }
    Ok(())
}

#[test]
fn fit_roundtrips_known_parameters() {
    prop::run("fit-roundtrip", 24, |rng| {
        let max_n = 12 + rng.gen_range(0, 3); // 12..=15 (inclusive draw)
        // w_t across the whole candidate range, both edges included:
        // 2 (minimum scanned) ..= max_n + 1 (no incast in the data).
        let w_t = 2 + rng.gen_range(0, max_n - 1);
        let p = ModelParams {
            alpha: draw(rng, 1e-3, 1e-2),
            beta: draw(rng, 2e-9, 2e-8),
            gamma: draw(rng, 1e-10, 1e-9),
            delta: draw(rng, 5e-11, 5e-10),
            epsilon: draw(rng, 5e-11, 5e-10),
            w_t,
        };
        let rows = synth_rows(&p, &[2e7, 5e7, 1e8], max_n);
        let f = fit(&rows).map_err(|e| e.to_string())?;
        // Whatever threshold the scan kept, the fit must reproduce the
        // data (the piecewise pieces can alias near the edges; times
        // cannot).
        check_prediction_roundtrip(&f, &rows)?;
        if f.rms_rel_residual > 1e-6 {
            return Err(format!("residual too large: {:.3e}", f.rms_rel_residual));
        }
        // With at least one n strictly above the threshold the incast
        // term is observable: full parameter recovery, threshold
        // included.
        if w_t < max_n {
            if f.w_t != w_t {
                return Err(format!("w_t: fitted {} vs true {w_t}", f.w_t));
            }
            for (name, got, want, tol) in [
                ("alpha", f.alpha, p.alpha, 1e-4),
                (
                    "2b+g",
                    f.two_beta_plus_gamma,
                    p.two_beta_plus_gamma(),
                    1e-4,
                ),
                ("delta", f.delta, p.delta, 1e-2),
                ("epsilon", f.epsilon, p.epsilon, 1e-3),
            ] {
                if rel(got, want) > tol {
                    return Err(format!("{name}: fitted {got:.6e} vs true {want:.6e}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn w_t_at_the_minimum_scan_candidate_is_recovered() {
    // w_t = 2: every n ≥ 3 pays incast — the scan's lowest candidate
    // must win, not an interior one compensating through ε.
    let p = ModelParams {
        w_t: 2,
        ..ModelParams::cpu_testbed()
    };
    let rows = synth_rows(&p, &[2e7, 1e8], 12);
    let f = fit(&rows).unwrap();
    assert_eq!(f.w_t, 2, "{f:?}");
    assert!(rel(f.epsilon, p.epsilon) < 1e-3, "eps {:.3e}", f.epsilon);
    assert!(rel(f.alpha, p.alpha) < 1e-4);
    check_prediction_roundtrip(&f, &rows).unwrap();
}

#[test]
fn w_t_past_the_data_means_no_observable_incast() {
    // w_t = max_n + 1: no row carries any incast excess — the scan's
    // highest candidate. The fit must reproduce the data exactly and
    // must not hallucinate an incast penalty for the swept range.
    let max_n = 15;
    let p = ModelParams {
        w_t: max_n + 1,
        ..ModelParams::cpu_testbed()
    };
    let rows = synth_rows(&p, &[2e7, 1e8], max_n);
    let f = fit(&rows).unwrap();
    assert!(f.rms_rel_residual < 1e-9, "{f:?}");
    check_prediction_roundtrip(&f, &rows).unwrap();
    // Either ε fitted to ~0, or the kept threshold charges no row in
    // the data — both mean "no incast observed".
    let max_excess = max_n.saturating_sub(f.w_t) as f64;
    let worst_penalty =
        2.0 * (max_n as f64 - 1.0) / max_n as f64 * 1e8 * max_excess * f.epsilon;
    let smallest_time = rows
        .iter()
        .map(|r| r.time)
        .fold(f64::INFINITY, f64::min);
    assert!(
        worst_penalty < smallest_time * 1e-6,
        "hallucinated incast: penalty {worst_penalty:.3e} (w_t {}, eps {:.3e})",
        f.w_t,
        f.epsilon
    );
}
