//! API-layer tests: the registry's applicability contract, cross-backend
//! agreement, and the typed-error guarantees of the coordinator service.

use genmodel::api::{applicable_specs, AlgoSpec, ApiError, Backend, Engine};
use genmodel::coordinator::{AllReduceService, ServiceConfig};
use genmodel::model::params::Environment;
use genmodel::plan::validate::{validate, Goal};
use genmodel::runtime::ReducerSpec;
use genmodel::topo::{builders, Topology};
use genmodel::util::prop;
use genmodel::util::rng::Rng;

/// Random tree topology: flat, asymmetric 2-level, or cross-DC.
fn random_topology(rng: &mut Rng) -> Topology {
    match rng.gen_range(0, 3) {
        0 => builders::single_switch(rng.gen_range(2, 24)),
        1 => {
            let mids = rng.gen_range(2, 5);
            let sizes: Vec<usize> = (0..mids).map(|_| rng.gen_range(1, 8)).collect();
            if sizes.iter().sum::<usize>() < 2 {
                builders::single_switch(4)
            } else {
                builders::asymmetric(&sizes, &[])
            }
        }
        _ => {
            let a: Vec<usize> = (0..rng.gen_range(1, 3)).map(|_| rng.gen_range(1, 6)).collect();
            let b: Vec<usize> = (0..rng.gen_range(1, 3)).map(|_| rng.gen_range(1, 6)).collect();
            if a.iter().chain(&b).sum::<usize>() < 2 {
                builders::single_switch(3)
            } else {
                builders::cross_dc(&a, &b)
            }
        }
    }
}

/// Every spec the registry reports applicable for a sampled topology
/// must build a plan that passes AllReduce validation, for the right
/// server count, and round-trip through `Display`/`FromStr`.
#[test]
fn prop_applicable_specs_build_valid_plans() {
    let env = Environment::paper();
    prop::run("registry-applicable-valid", 48, |rng| {
        let topo = random_topology(rng);
        let s = 10f64.powf(rng.gen_range(4, 8) as f64);
        let specs = applicable_specs(&topo);
        if topo.n_servers() >= 2 && specs.len() < 3 {
            return Err(format!(
                "{}: suspiciously few applicable algorithms: {specs:?}",
                topo.name
            ));
        }
        for spec in specs {
            let plan = spec
                .build(&topo, &env, s)
                .map_err(|e| format!("{}: {spec}: {e}", topo.name))?;
            validate(&plan, Goal::AllReduce)
                .map_err(|e| format!("{}: {spec}: {e}", topo.name))?;
            if plan.n_servers != topo.n_servers() {
                return Err(format!("{spec}: plan n={} topo n={}", plan.n_servers, topo.n_servers()));
            }
            let reparsed: AlgoSpec = spec
                .to_string()
                .parse()
                .map_err(|e: ApiError| format!("{spec}: reparse: {e}"))?;
            if reparsed != spec {
                return Err(format!("{spec}: display/parse roundtrip broke: {reparsed}"));
            }
        }
        Ok(())
    });
}

/// On a single switch, the analytic GenModel backend and the flow
/// simulator agree within tolerance for every applicable algorithm —
/// the Fig. 8 accuracy claim as a property.
#[test]
fn prop_analytic_and_simulated_agree_on_single_switch() {
    let env = Environment::paper();
    prop::run("model-vs-sim-single-switch", 24, |rng| {
        let n = rng.gen_range(2, 12);
        let s = 10f64.powf(rng.gen_range(4, 8) as f64);
        let engine = Engine::new(builders::single_switch(n), env.clone());
        for algo in engine.algorithms() {
            let evs = engine
                .compare(&algo, s, &[Backend::Analytic, Backend::Simulated])
                .map_err(|e| format!("n={n}: {algo}: {e}"))?;
            let (model, sim) = (evs[0].seconds, evs[1].seconds);
            if !(model.is_finite() && sim.is_finite() && model > 0.0 && sim > 0.0) {
                return Err(format!("n={n} {algo}: degenerate times {model} / {sim}"));
            }
            let rel = (model - sim).abs() / sim;
            if rel > 0.12 {
                return Err(format!(
                    "n={n} S={s:.0e} {algo}: model {model:.5}s vs sim {sim:.5}s (rel {rel:.3})"
                ));
            }
        }
        Ok(())
    });
}

/// The executed backend agrees with itself across algorithms: every
/// applicable algorithm reduces the same inputs to the same (oracle)
/// result — verification happens inside the backend.
#[test]
fn prop_executed_backend_verifies_for_every_algorithm() {
    let env = Environment::paper();
    prop::run("exec-all-algorithms", 12, |rng| {
        let n = rng.gen_range(2, 9);
        let s = rng.gen_range(3, 4000) as f64;
        let engine = Engine::new(builders::single_switch(n), env.clone());
        for algo in engine.algorithms() {
            let ev = engine
                .evaluate(&algo, s, Backend::Executed)
                .map_err(|e| format!("n={n} {algo}: {e}"))?;
            let x = ev.exec.ok_or_else(|| format!("{algo}: no exec report"))?;
            if !x.verified {
                return Err(format!("{algo}: not verified"));
            }
        }
        Ok(())
    });
}

#[test]
fn submit_on_stopped_service_is_typed_error() {
    let svc = AllReduceService::start(
        builders::single_switch(3),
        Environment::paper(),
        ReducerSpec::Scalar,
        ServiceConfig::default(),
    );
    let ts = |seed| {
        let mut rng = Rng::new(seed);
        (0..3).map(|_| rng.f32_vec(16)).collect::<Vec<_>>()
    };
    svc.allreduce(ts(1)).unwrap();
    svc.stop();
    assert_eq!(svc.submit(ts(2)).err(), Some(ApiError::ServiceStopped));
}

#[test]
fn wrong_tensor_count_is_typed_error_end_to_end() {
    let svc = AllReduceService::start(
        builders::single_switch(4),
        Environment::paper(),
        ReducerSpec::Scalar,
        ServiceConfig::default(),
    );
    let mut rng = Rng::new(0);
    let three: Vec<Vec<f32>> = (0..3).map(|_| rng.f32_vec(8)).collect();
    match svc.submit(three) {
        Err(ApiError::BadRequest { reason }) => assert!(reason.contains("tensor")),
        other => panic!("expected BadRequest, got {:?}", other.map(|_| ())),
    }
}

/// `repro predict --algo X --backend model|sim|exec` works for every
/// registered algorithm — here as the library calls the CLI makes.
#[test]
fn every_registered_algorithm_evaluates_on_every_backend() {
    let engine = Engine::new(builders::single_switch(8), Environment::paper());
    let algos = engine.algorithms();
    // All seven families are applicable on 8 servers (power of two,
    // composite): gentree, gentree-star, rhd, ring, cps, hcps, rb, acps.
    assert!(algos.len() >= 7, "expected the full registry, got {algos:?}");
    for algo in &algos {
        for backend in Backend::ALL {
            let s = if backend == Backend::Executed { 2000.0 } else { 1e7 };
            let ev = engine
                .evaluate(algo, s, backend)
                .unwrap_or_else(|e| panic!("{algo} on {backend}: {e}"));
            assert!(ev.seconds > 0.0, "{algo} on {backend}: zero time");
        }
    }
}
