//! Property-style pins for the job lifecycle decomposition
//! (`queued → drained → batched → executed`) and the SLO burn-rate
//! monitor built on top of it.
//!
//! The claims, stated as tests: every job's stage durations sum
//! *exactly* to its reported e2e (the identity is structural, not
//! approximate); the traced stage chain is monotone per job — queued
//! opens the timeline, drained begins where queued ends, and the chain
//! fits inside the done span; under 8 concurrent producers on the wall
//! clock the decomposition stays within clock-read skew of the e2e the
//! submitter actually measured; and the SLO tracker trips exactly when
//! injected latency crosses the objective (impossible objective → one
//! trip with hysteresis; generous objective → zero, the honest control).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use genmodel::coordinator::{AllReduceService, BatchPolicy, ObserveMode, ServiceConfig};
use genmodel::model::params::Environment;
use genmodel::runtime::ReducerSpec;
use genmodel::telemetry::SloPolicy;
use genmodel::topo::builders::single_switch;
use genmodel::trace::{SpanKind, TraceRecorder};

const WORKERS: usize = 4;

fn service(cfg: ServiceConfig) -> AllReduceService {
    AllReduceService::start(
        single_switch(WORKERS),
        Environment::paper(),
        ReducerSpec::Scalar,
        cfg,
    )
}

fn tensors(len: usize) -> Vec<Vec<f32>> {
    (0..WORKERS).map(|_| vec![1.0f32; len]).collect()
}

/// Sim clock, traced: the structural identity per result, then the same
/// story retold by the trace — per job, monotone and self-consistent.
#[test]
fn every_job_reports_a_monotone_stage_chain_summing_to_its_e2e() {
    const JOBS: usize = 16;
    let trace = Arc::new(TraceRecorder::new());
    let svc = service(
        ServiceConfig {
            observe: ObserveMode::Sim,
            policy: BatchPolicy::with_cap(4),
            flush_after: Duration::from_micros(200),
            ..ServiceConfig::default()
        }
        .with_trace(trace.clone()),
    );
    let handles: Vec<_> = (0..JOBS)
        .map(|_| svc.submit(tensors(512)))
        .collect::<Result<_, _>>()
        .unwrap();
    for h in handles {
        let res = h.recv().unwrap().unwrap();
        let st = &res.stages;
        assert_eq!(
            st.e2e_ns(),
            st.queued_ns + st.drained_ns + st.batched_ns + st.exec_ns,
            "e2e is the exact structural sum of its stages"
        );
        assert!(st.exec_ns > 0, "the sim clock prices every batch > 0");
    }
    svc.stop();
    let snap = trace.snapshot();
    assert_eq!(snap.dropped, 0, "the smoke must fit the ring");
    assert!(
        snap.incomplete_jobs().is_empty(),
        "every queued job retired"
    );
    let done: HashMap<u64, u64> = snap
        .of_kind(SpanKind::JobDone)
        .map(|e| (e.span.job, e.span.dur_ns))
        .collect();
    assert_eq!(done.len(), JOBS, "one done span per job");
    let queued: HashMap<u64, (u64, u64)> = snap
        .of_kind(SpanKind::JobQueued)
        .map(|e| (e.span.job, (e.span.ts_ns, e.span.dur_ns)))
        .collect();
    assert_eq!(queued.len(), JOBS, "one queued span per job");
    for dr in snap.of_kind(SpanKind::JobDrained) {
        let (q_ts, q_dur) = queued[&dr.span.job];
        assert_eq!(
            dr.span.ts_ns,
            q_ts + q_dur,
            "job {}: drained begins exactly where queued ends",
            dr.span.job
        );
        assert!(
            dr.span.ts_ns + dr.span.dur_ns <= q_ts + done[&dr.span.job],
            "job {}: the stage chain fits inside its done span",
            dr.span.job
        );
    }
}

/// Wall clock, 8 producers hammering 4 lanes: every stage stamp lies
/// inside the submitter's own submit → recv window, so the reported e2e
/// can exceed the measured wall e2e only by clock-read skew.
#[test]
fn stage_sums_track_wall_e2e_under_8_concurrent_producers() {
    const PRODUCERS: usize = 8;
    const PER_PRODUCER: usize = 32;
    let svc = service(ServiceConfig {
        observe: ObserveMode::Wall,
        policy: BatchPolicy::with_cap(1 << 20),
        flush_after: Duration::from_micros(200),
        ingest_lanes: 4,
        ..ServiceConfig::default()
    });
    let checked = std::thread::scope(|s| {
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|_| {
                let svc = &svc;
                s.spawn(move || {
                    for _ in 0..PER_PRODUCER {
                        let t0 = std::time::Instant::now();
                        let rx = svc.submit(tensors(256)).unwrap();
                        let res = rx.recv().unwrap().unwrap();
                        let wall = t0.elapsed().as_secs_f64();
                        let st = &res.stages;
                        assert_eq!(
                            st.e2e_ns(),
                            st.queued_ns + st.drained_ns + st.batched_ns + st.exec_ns
                        );
                        let e2e = st.e2e_secs();
                        assert!(e2e > 0.0, "a served job took time");
                        assert!(
                            e2e <= wall + 0.010,
                            "decomposed e2e {e2e}s exceeds the measured \
                             submit→recv wall {wall}s by more than clock skew"
                        );
                    }
                    PER_PRODUCER
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("producer panicked"))
            .sum::<usize>()
    });
    assert_eq!(checked, PRODUCERS * PER_PRODUCER);
    svc.stop();
}

/// Injected violation: a 0-second objective every Sim-priced job must
/// miss. The tracker trips exactly once (hysteresis holds it tripped
/// instead of re-tripping per job) and the trip surfaces in metrics.
#[test]
fn slo_trips_exactly_when_injected_latency_crosses_the_objective() {
    const JOBS: u64 = 6;
    let svc = service(ServiceConfig {
        observe: ObserveMode::Sim,
        policy: BatchPolicy::with_cap(1),
        flush_after: Duration::from_micros(100),
        slo: Some(SloPolicy {
            objective_secs: 0.0,
            fast_window: 2,
            slow_window: 2,
            budget: 1.0,
        }),
        ..ServiceConfig::default()
    });
    for _ in 0..JOBS {
        svc.submit(tensors(512)).unwrap().recv().unwrap().unwrap();
    }
    let snap = svc.slo_snapshot().expect("slo was configured");
    assert_eq!(snap.observed, JOBS);
    assert_eq!(snap.violations, JOBS, "no job beats a 0-second objective");
    assert_eq!(snap.trips, 1, "hysteresis: one trip, not one per job");
    assert!(snap.tripped);
    assert_eq!(svc.metrics.snapshot().slo_trips, 1);
    svc.stop();
}

/// The honest control: a generous objective no smoke can miss records
/// observations but neither violations nor trips.
#[test]
fn generous_slo_never_trips_the_honest_control() {
    const JOBS: u64 = 6;
    let svc = service(ServiceConfig {
        observe: ObserveMode::Sim,
        policy: BatchPolicy::with_cap(1),
        flush_after: Duration::from_micros(100),
        slo: Some(SloPolicy::new(3600.0)),
        ..ServiceConfig::default()
    });
    for _ in 0..JOBS {
        svc.submit(tensors(512)).unwrap().recv().unwrap().unwrap();
    }
    let snap = svc.slo_snapshot().expect("slo was configured");
    assert_eq!(snap.observed, JOBS);
    assert_eq!(snap.violations, 0);
    assert_eq!(snap.trips, 0);
    assert!(!snap.tripped);
    assert_eq!(svc.metrics.snapshot().slo_trips, 0);
    svc.stop();
}
