//! Property tests for the selection-aware batcher: random FIFO queues
//! and random policies (with and without selection split points) must
//! never lose, duplicate, or reorder a job; must respect the size cap
//! except for lone oversized jobs; and every split decision must land
//! the emitted batch inside the bucket it claims, at a margin that
//! clears the policy threshold.

use genmodel::coordinator::{
    plan_batches, BatchPolicy, BatchRule, PendingJob, PlanRouter, PlannedBatch, SplitPoints,
};
use genmodel::util::rng::Rng;

fn random_queue(rng: &mut Rng, max_len: usize) -> Vec<PendingJob> {
    let len = rng.gen_range(0, max_len);
    (0..len)
        .map(|i| PendingJob {
            id: i as u64,
            // Spans several router buckets on either side of typical caps.
            floats: rng.gen_range(1, 300_000),
        })
        .collect()
}

/// A random policy; `margin_range` bounds the split-point margins, so
/// callers can force all-weak or all-strong boundaries.
fn random_policy(rng: &mut Rng, with_table: bool, margin_range: (f64, f64)) -> BatchPolicy {
    let mut policy = BatchPolicy::with_cap(rng.gen_range(1_000, 2_000_000));
    policy.min_split_margin = 1.25;
    if with_table {
        let (lo, hi) = margin_range;
        let points: Vec<(u32, f64)> = (0..rng.gen_range(1, 4))
            .map(|_| {
                (
                    rng.gen_range(11, 20) as u32,
                    lo + rng.next_f64() * (hi - lo),
                )
            })
            .collect();
        policy.selection = Some(SplitPoints::new(points));
    }
    policy
}

fn flatten(batches: &[PlannedBatch]) -> Vec<PendingJob> {
    batches.iter().flat_map(|b| b.jobs.iter().copied()).collect()
}

#[test]
fn no_job_lost_duplicated_or_reordered() {
    let mut rng = Rng::new(0xBA7C4E5);
    for case in 0..400 {
        let queue = random_queue(&mut rng, 40);
        let policy = random_policy(&mut rng, case % 2 == 0, (1.0, 4.0));
        let batches = plan_batches(&queue, &policy);
        assert_eq!(flatten(&batches), queue, "case {case}: {policy:?}");
        assert!(
            batches.iter().all(|b| !b.jobs.is_empty()),
            "case {case}: empty batch emitted"
        );
    }
}

#[test]
fn cap_respected_unless_single_oversized() {
    let mut rng = Rng::new(0xCA9F00D);
    for case in 0..400 {
        let queue = random_queue(&mut rng, 40);
        let policy = random_policy(&mut rng, case % 2 == 0, (1.0, 4.0));
        for b in plan_batches(&queue, &policy) {
            if b.fused_floats() > policy.bucket_floats {
                assert_eq!(b.jobs.len(), 1, "case {case}: multi-job batch over cap");
                assert_eq!(b.rule, BatchRule::Oversized, "case {case}");
            } else {
                assert_ne!(b.rule, BatchRule::Oversized, "case {case}");
            }
        }
    }
}

#[test]
fn split_decisions_land_inside_the_claimed_bucket() {
    let mut rng = Rng::new(0x59117B0);
    let mut splits_seen = 0usize;
    // One crafted must-split case (3000+3000 stopped before 20_000 drags
    // the fuse across a decisive bucket-14 boundary) guarantees the
    // sweep exercises the rule even if the random draw is unlucky.
    let crafted_queue: Vec<PendingJob> = [3000usize, 3000, 20_000]
        .iter()
        .enumerate()
        .map(|(i, &floats)| PendingJob { id: i as u64, floats })
        .collect();
    let mut crafted_policy = BatchPolicy::with_cap(1 << 22);
    crafted_policy.selection = Some(SplitPoints::new(vec![(14, 3.0)]));
    for case in 0..=400 {
        let (queue, policy) = if case == 400 {
            (crafted_queue.clone(), crafted_policy.clone())
        } else {
            let queue = random_queue(&mut rng, 40);
            let policy = random_policy(&mut rng, true, (1.0, 4.0));
            (queue, policy)
        };
        for b in plan_batches(&queue, &policy) {
            if let BatchRule::SplitAtBucket { bucket, margin } = b.rule {
                splits_seen += 1;
                assert_eq!(
                    PlanRouter::bucket(b.fused_floats()),
                    bucket,
                    "case {case}: batch of {} floats claims bucket {bucket}",
                    b.fused_floats()
                );
                assert!(
                    margin >= policy.min_split_margin,
                    "case {case}: split at margin {margin} < {}",
                    policy.min_split_margin
                );
            }
        }
    }
    assert!(splits_seen > 0, "the sweep never exercised a split");
}

#[test]
fn drained_closes_only_the_final_batch() {
    let mut rng = Rng::new(0xD8A1AED);
    for case in 0..400 {
        let queue = random_queue(&mut rng, 40);
        let policy = random_policy(&mut rng, case % 2 == 0, (1.0, 4.0));
        let batches = plan_batches(&queue, &policy);
        for (i, b) in batches.iter().enumerate() {
            if i + 1 < batches.len() {
                assert_ne!(b.rule, BatchRule::Drained, "case {case}: batch {i}");
            } else {
                assert!(
                    matches!(b.rule, BatchRule::Drained | BatchRule::Oversized),
                    "case {case}: final batch closed by {:?}",
                    b.rule
                );
            }
        }
    }
}

#[test]
fn below_threshold_margins_reproduce_the_cap_only_partition() {
    // The acceptance regression: when every boundary margin is below
    // min_split_margin, the selection-aware batcher is byte-identical to
    // the historical cap-only policy — batches, rules, everything.
    let mut rng = Rng::new(0x0E64E55);
    for case in 0..300 {
        let queue = random_queue(&mut rng, 40);
        let weak = random_policy(&mut rng, true, (1.0, 1.2499));
        let cap_only = BatchPolicy::with_cap(weak.bucket_floats);
        assert_eq!(
            plan_batches(&queue, &weak),
            plan_batches(&queue, &cap_only),
            "case {case}: weak boundaries changed the partition"
        );
    }
}

#[test]
fn empty_split_points_behave_like_no_table() {
    let mut rng = Rng::new(0xE66);
    for _ in 0..100 {
        let queue = random_queue(&mut rng, 30);
        let cap = rng.gen_range(1_000, 2_000_000);
        let mut with_empty = BatchPolicy::with_cap(cap);
        with_empty.selection = Some(SplitPoints::new(Vec::new()));
        assert_eq!(
            plan_batches(&queue, &with_empty),
            plan_batches(&queue, &BatchPolicy::with_cap(cap))
        );
    }
}
