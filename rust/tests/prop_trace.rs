//! Property tests for the flight recorder: bounded memory with an exact
//! drop counter, no torn events under concurrent producers, monotone
//! sequence numbers in every snapshot, the one-atomic-load idle gate,
//! a seeded JSONL roundtrip sweep, and the `trace/v1` golden fixture
//! pinning the artifact's exact bytes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use genmodel::trace::{Span, SpanEvent, SpanKind, TraceRecorder, TraceSnapshot};
use genmodel::util::rng::Rng;

/// A span whose every variable field is derived from one value, so a
/// torn read (words from two different writers) is detectable: any
/// decoded event must satisfy [`coherent`].
fn stamped(v: u64) -> Span {
    let mut s = Span::new(SpanKind::BatchExec);
    s.job = v;
    s.epoch = v;
    s.ts_ns = v;
    s.dur_ns = v;
    s.floats = v;
    s.phase = (v & 0xffff) as u32;
    s.fanin = ((v >> 16) & 0xffff) as u32;
    s.attr = [v as f64; 5];
    s
}

fn coherent(e: &SpanEvent) -> bool {
    let s = &e.span;
    let v = s.job;
    s.epoch == v
        && s.ts_ns == v
        && s.dur_ns == v
        && s.floats == v
        && s.phase == (v & 0xffff) as u32
        && s.fanin == ((v >> 16) & 0xffff) as u32
        && s.attr.iter().all(|a| *a == v as f64)
}

#[test]
fn ring_is_bounded_and_counts_drops_exactly() {
    for (cap, n) in [(1usize, 10u64), (8, 8), (8, 9), (64, 1000), (128, 50)] {
        let rec = TraceRecorder::with_capacity(cap);
        for i in 0..n {
            rec.record(&stamped(i));
        }
        let snap = rec.snapshot();
        let retained = n.min(cap as u64);
        assert_eq!(snap.events.len() as u64, retained, "cap={cap} n={n}");
        assert_eq!(snap.dropped, n.saturating_sub(cap as u64), "cap={cap} n={n}");
        assert_eq!(rec.dropped(), snap.dropped);
        assert_eq!(rec.recorded(), n);
        // Exactly the NEWEST events survive, sequence-ascending.
        let seqs: Vec<u64> = snap.events.iter().map(|e| e.seq).collect();
        let want: Vec<u64> = (n - retained..n).collect();
        assert_eq!(seqs, want, "cap={cap} n={n}");
        for e in &snap.events {
            assert!(coherent(e), "cap={cap} n={n}: torn single-threaded event {e:?}");
            assert_eq!(e.seq, e.span.job, "payload tracks its claimed sequence");
        }
    }
}

#[test]
fn concurrent_producers_never_publish_torn_events() {
    const THREADS: u64 = 4;
    const PER_THREAD: u64 = 5_000;
    // Small ring: producers lap it constantly, so reader/writer collisions
    // on the same slot are the common case, not the rare one.
    let rec = Arc::new(TraceRecorder::with_capacity(32));
    let stop = Arc::new(AtomicBool::new(false));

    // A reader hammering snapshots while the writers run: every event it
    // ever observes must be coherent and every snapshot seq-monotone.
    let reader = {
        let rec = rec.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut taken = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let snap = rec.snapshot();
                let mut last: Option<u64> = None;
                for e in &snap.events {
                    assert!(coherent(e), "torn event under contention: {e:?}");
                    if let Some(prev) = last {
                        assert!(e.seq > prev, "non-monotone seq {} after {prev}", e.seq);
                    }
                    last = Some(e.seq);
                }
                taken += 1;
            }
            taken
        })
    };
    let writers: Vec<_> = (0..THREADS)
        .map(|t| {
            let rec = rec.clone();
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    // Distinct value per (thread, iteration) — a torn mix
                    // of two writers can never masquerade as coherent.
                    rec.record(&stamped(t * PER_THREAD + i));
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let snapshots_taken = reader.join().unwrap();
    assert!(snapshots_taken > 0, "the reader must actually have contended");

    // Quiescent accounting is exact: every record claimed one sequence.
    assert_eq!(rec.recorded(), THREADS * PER_THREAD);
    assert_eq!(rec.dropped(), THREADS * PER_THREAD - 32);
    let snap = rec.snapshot();
    assert_eq!(snap.events.len(), 32, "a quiet ring retains exactly capacity");
    for e in &snap.events {
        assert!(coherent(e));
    }
}

#[test]
fn disabled_recorder_is_inert_even_under_threads() {
    // The enabled-but-idle contract's disabled half: record() from many
    // threads claims nothing, so there is no sequence churn, no drops,
    // and nothing to snapshot — the whole recorder is one cold load.
    let rec = Arc::new(TraceRecorder::with_capacity(16));
    rec.set_enabled(false);
    let writers: Vec<_> = (0..4)
        .map(|t| {
            let rec = rec.clone();
            std::thread::spawn(move || {
                for i in 0..1_000u64 {
                    rec.record(&stamped(t * 1_000 + i));
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    assert_eq!(rec.recorded(), 0);
    assert_eq!(rec.dropped(), 0);
    assert!(rec.snapshot().events.is_empty());
}

/// Random spans of every kind survive the JSONL roundtrip semantically:
/// same kind, resolved names, scalar fields, and (for attributed kinds)
/// the five term seconds. Ids may be renumbered by the parser's
/// re-interning, so the comparison goes through resolved names.
#[test]
fn jsonl_roundtrip_sweep_preserves_every_field() {
    let mut rng = Rng::new(0x7ace);
    let names = ["single:4", "single:15", "sym:2,4", "cps", "ring", "hcps:5x3", ""];
    for round in 0..50 {
        let rec = TraceRecorder::with_capacity(64);
        let n_events = 1 + (rng.next_u64() % 40) as usize;
        for _ in 0..n_events {
            let kind = SpanKind::ALL[(rng.next_u64() % SpanKind::ALL.len() as u64) as usize];
            let mut s = Span::new(kind);
            s.class = rec.intern(names[(rng.next_u64() % names.len() as u64) as usize]);
            s.algo = rec.intern(names[(rng.next_u64() % names.len() as u64) as usize]);
            s.job = rng.next_u64() % (1 << 48);
            s.phase = (rng.next_u64() % 64) as u32;
            s.fanin = (rng.next_u64() % 64) as u32;
            s.epoch = rng.next_u64() % 1024;
            s.ts_ns = rng.next_u64() % (1 << 50);
            s.dur_ns = rng.next_u64() % (1 << 40);
            s.floats = rng.next_u64() % (1 << 40);
            if kind.attributed() {
                // Finite, sign-mixed term seconds (unexplained may be
                // negative — over-prediction).
                s.attr = [
                    rng.next_f64(),
                    rng.next_f64() * 2.0,
                    -rng.next_f64(),
                    rng.next_f64() * 0.5,
                    rng.next_f64() - 0.5,
                ];
            }
            rec.record(&s);
        }
        let snap = rec.snapshot();
        let back = TraceSnapshot::from_jsonl(&snap.to_jsonl()).unwrap();
        assert_eq!(back.events.len(), snap.events.len(), "round {round}");
        assert_eq!(back.dropped, snap.dropped);
        for (a, b) in snap.events.iter().zip(&back.events) {
            assert_eq!(a.seq, b.seq);
            assert_eq!(a.span.kind, b.span.kind);
            assert_eq!(snap.name(a.span.class), back.name(b.span.class));
            assert_eq!(snap.name(a.span.algo), back.name(b.span.algo));
            assert_eq!(a.span.job, b.span.job);
            assert_eq!(a.span.phase, b.span.phase);
            assert_eq!(a.span.fanin, b.span.fanin);
            assert_eq!(a.span.epoch, b.span.epoch);
            assert_eq!(a.span.ts_ns, b.span.ts_ns);
            assert_eq!(a.span.dur_ns, b.span.dur_ns);
            assert_eq!(a.span.floats, b.span.floats);
            if a.span.kind.attributed() {
                assert_eq!(a.span.attr, b.span.attr, "round {round}");
            }
        }
        // Canonical form is a fixed point.
        assert_eq!(back.to_jsonl(), snap.to_jsonl());
    }
}

/// The golden fixture: `trace/v1` is an on-disk contract, so its exact
/// bytes are pinned. Regenerating this file is a schema change — bump
/// [`genmodel::trace::SCHEMA`] and say so in the commit.
#[test]
fn golden_fixture_pins_trace_v1_bytes() {
    const GOLDEN: &str = include_str!("fixtures/trace_smoke.json");

    // The same deterministic two-event story as the exporter's unit
    // sample: one flush marker, one attributed exec span, 4 drops.
    let mut flush = Span::new(SpanKind::BatchFlush);
    flush.class = 1;
    flush.job = 3;
    flush.ts_ns = 500;
    flush.floats = 4096;
    let mut exec = Span::new(SpanKind::BatchExec);
    exec.class = 1;
    exec.algo = 2;
    exec.job = 3;
    exec.epoch = 1;
    exec.ts_ns = 1_000;
    exec.dur_ns = 2_500;
    exec.floats = 4096;
    exec.fanin = 3;
    exec.attr = [0.5, 0.25, 1.5, 0.125, -0.375];
    let snap = TraceSnapshot {
        events: vec![
            SpanEvent { seq: 4, span: flush },
            SpanEvent { seq: 5, span: exec },
        ],
        dropped: 4,
        strings: vec!["".into(), "single:4".into(), "cps".into()],
    };

    assert_eq!(snap.to_jsonl(), GOLDEN, "trace/v1 byte layout changed");
    let parsed = TraceSnapshot::from_jsonl(GOLDEN).unwrap();
    assert_eq!(parsed, snap, "golden fixture no longer parses to the sample");
    assert_eq!(parsed.attributed_execs(), 1);
}
