//! Drift autopilot end-to-end: a service starts under a deliberately
//! mis-parameterized selection table (blind δ=ε=0 winners, served on an
//! ε×20 congested fabric — the `telemetry_e2e.rs` setup), the
//! `DriftMonitor` trips on the observed misprediction, recalibrates the
//! offending cell under the true environment, and hot-swaps the table
//! mid-serve: stale router plans are evicted, no job is dropped or
//! duplicated, and post-swap jobs report the new epoch and the genuinely
//! cheaper winner while untouched buckets keep routing as before.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use genmodel::api::{AlgoSpec, Engine};
use genmodel::campaign::table_from_model;
use genmodel::coordinator::{
    AllReduceService, BatchPolicy, DriftConfig, ObserveMode, ServiceConfig,
};
use genmodel::model::params::{Environment, ModelParams};
use genmodel::runtime::ReducerSpec;
use genmodel::telemetry::Recorder;
use genmodel::topo::builders::single_switch;
use genmodel::util::rng::Rng;

fn tensors(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f32_vec(len)).collect()
}

fn oracle(ts: &[Vec<f32>]) -> Vec<f32> {
    genmodel::exec::oracle_sum(&ts.to_vec())
}

/// The "true" fabric: the paper's CPU testbed with a 20× incast slope.
fn true_params() -> ModelParams {
    let p = ModelParams::cpu_testbed();
    ModelParams {
        epsilon: p.epsilon * 20.0,
        ..p
    }
}

/// The classic (α,β,γ) worldview the stale table was priced under.
fn stale_params() -> ModelParams {
    ModelParams {
        delta: 0.0,
        epsilon: 0.0,
        ..ModelParams::cpu_testbed()
    }
}

fn candidates() -> Vec<AlgoSpec> {
    vec![
        AlgoSpec::Cps,
        AlgoSpec::Hcps { factors: vec![5, 3] },
        AlgoSpec::Ring,
    ]
}

#[test]
fn drift_is_detected_recalibrated_and_hot_swapped_mid_serve() {
    const N: usize = 15;
    const BIG: usize = 1 << 20; // bucket 20: the incast-dominated cell
    const SMALL: usize = 65_536; // bucket 16: incast-free, stays honest

    // The stale table: winners for buckets 16 and 20 derived under the
    // blind parameters — CPS everywhere (fewest rounds, optimal
    // bandwidth), exactly what the classic model concludes.
    let grid: BTreeMap<String, BTreeSet<u32>> =
        BTreeMap::from([(format!("single:{N}"), BTreeSet::from([16u32, 20]))]);
    let stale =
        table_from_model(&grid, &candidates(), &Environment::uniform(stale_params())).unwrap();
    let stale_choice = stale.lookup("single:15", BIG).unwrap().clone();
    assert_eq!(stale_choice.algo, "cps", "the blind model routes cps");

    let recorder = Arc::new(Recorder::new());
    let cfg = ServiceConfig {
        policy: BatchPolicy::with_cap(1), // every job its own batch
        flush_after: Duration::from_millis(1),
        observe: ObserveMode::Sim, // deterministic observed seconds
        drift: Some(DriftConfig {
            threshold: 0.5,
            every: 4, // check after every 4th flushed batch
            algos: candidates(),
            ..DriftConfig::default()
        }),
        ..ServiceConfig::default()
    }
    .with_selection_table(&stale, "single:15", 1.25)
    .unwrap()
    .with_telemetry(recorder.clone(), "single:15");
    let svc = AllReduceService::start(
        single_switch(N),
        Environment::uniform(true_params()), // the fabric reality
        ReducerSpec::Scalar,
        cfg,
    );
    assert_eq!(svc.table_epoch(), Some(0));

    // Phase 1 — four sequential big jobs under the stale table: each is
    // served by the stale winner at epoch 0, numerically correct, while
    // the sim clock records the congested fabric's (much slower) truth.
    for i in 0..4u64 {
        let ts = tensors(N, BIG, i);
        let want = oracle(&ts);
        let res = svc.allreduce(ts).unwrap();
        assert_eq!(res.algo, "cps", "pre-swap job {i} routed the stale winner");
        assert_eq!(res.epoch, 0, "pre-swap job {i} carries epoch 0");
        for (a, b) in res.reduced.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "job {i}: {a} vs {b}");
        }
    }

    // The 4th batch reached the check cadence: the monitor scored the
    // (single:15, 2^20, cps) cell, saw |rel err| ≫ 50%, re-priced the
    // offending cell under the true environment (the calibrator path
    // needs a multi-n spread this single rack cannot give), and swapped.
    // The swap happens on the leader thread between flush cycles, so the
    // very next job is served by the new table.

    // Phase 2 — post-swap jobs report the new epoch and the new winner.
    for i in 4..6u64 {
        let ts = tensors(N, BIG, i);
        let want = oracle(&ts);
        let res = svc.allreduce(ts).unwrap();
        assert_eq!(res.epoch, 1, "post-swap job {i} carries the new epoch");
        assert_eq!(
            res.algo, "hcps:5x3",
            "post-swap job {i} routes the recalibrated winner"
        );
        for (a, b) in res.reduced.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "job {i}: {a} vs {b}");
        }
    }
    // The recalibrated winner is genuinely cheaper under the true
    // parameters — the swap moved routing toward reality, not just away
    // from the old table.
    let truth = Engine::new(single_switch(N), Environment::uniform(true_params()));
    let new_s = truth
        .predict_bucket(&AlgoSpec::Hcps { factors: vec![5, 3] }, 20)
        .unwrap();
    let old_s = truth.predict_bucket(&AlgoSpec::Cps, 20).unwrap();
    assert!(new_s < old_s, "{new_s} vs {old_s}");

    // The un-offending small bucket kept its winner: the recalibration
    // merge is surgical, and the same (new) epoch serves it.
    let res = svc.allreduce(tensors(N, SMALL, 9)).unwrap();
    assert_eq!(res.algo, "cps", "incast-free bucket keeps its winner");
    assert_eq!(res.epoch, 1, "all consumers observe the same epoch");

    svc.stop();
    assert_eq!(svc.table_epoch(), Some(1));
    let m = svc.metrics.snapshot();
    assert_eq!(m.drift_swaps, 1, "exactly one swap");
    assert_eq!(m.drift_failures, 0);
    assert!(m.drift_checks >= 1);
    assert_eq!(m.drift_epoch, 1);
    assert!(
        m.drift_evictions >= 1,
        "the stale (cps, 2^20) router plan must be evicted at swap"
    );
    // Zero dropped / duplicated jobs across the swap: every submission
    // above got exactly one (verified) result, and the counters agree.
    assert_eq!(m.jobs_submitted, 7);
    assert_eq!(m.jobs_completed, 7);
    assert!(m.rules_consistent());

    // The recorder saw both generations under their own algorithms —
    // post-swap traffic lands in the new winner's cell, so the monitor's
    // next delta scores the new table against its own serving.
    let snap = recorder.snapshot();
    let cells: Vec<String> = snap.cells.keys().map(|k| k.to_string()).collect();
    assert!(
        cells.iter().any(|k| k.contains("cps") && k.contains("2^20")),
        "{cells:?}"
    );
    assert!(
        cells.iter().any(|k| k.contains("hcps:5x3")),
        "{cells:?}"
    );
}

#[test]
fn honest_table_never_swaps() {
    // Control: the same service shape under a table priced with the TRUE
    // parameters — the monitor checks but never trips, the epoch stays
    // 0, and routing is stable throughout.
    const N: usize = 15;
    let grid: BTreeMap<String, BTreeSet<u32>> =
        BTreeMap::from([(format!("single:{N}"), BTreeSet::from([20u32]))]);
    let honest =
        table_from_model(&grid, &candidates(), &Environment::uniform(true_params())).unwrap();
    let winner = honest.lookup("single:15", 1 << 20).unwrap().algo.clone();
    let cfg = ServiceConfig {
        policy: BatchPolicy::with_cap(1),
        flush_after: Duration::from_millis(1),
        observe: ObserveMode::Sim,
        drift: Some(DriftConfig {
            threshold: 0.5,
            every: 2,
            algos: candidates(),
            ..DriftConfig::default()
        }),
        ..ServiceConfig::default()
    }
    .with_selection_table(&honest, "single:15", 1.25)
    .unwrap();
    let svc = AllReduceService::start(
        single_switch(N),
        Environment::uniform(true_params()),
        ReducerSpec::Scalar,
        cfg,
    );
    for i in 0..4u64 {
        let res = svc.allreduce(tensors(N, 1 << 20, i)).unwrap();
        assert_eq!(res.algo, winner);
        assert_eq!(res.epoch, 0);
    }
    svc.stop();
    let m = svc.metrics.snapshot();
    assert!(m.drift_checks >= 1, "the monitor did run");
    assert_eq!(m.drift_swaps, 0, "an accurate table is left alone");
    assert_eq!(svc.table_epoch(), Some(0));
}
