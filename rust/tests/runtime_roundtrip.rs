//! PJRT round-trip tests: the AOT artifacts loaded and executed from rust
//! must match the scalar oracle bit-for-bit tolerances aside.
//! Requires the `pjrt` feature and `make artifacts` (skips gracefully
//! otherwise).
#![cfg(feature = "pjrt")]

use genmodel::runtime::{Artifacts, Reducer};
use genmodel::util::rng::Rng;

fn arts() -> Option<std::sync::Arc<Artifacts>> {
    Artifacts::load_default().ok().map(std::sync::Arc::new)
}

fn rand_rows(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k).map(|_| rng.f32_vec(n)).collect()
}

fn close(a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= 1e-3 * x.abs().max(1.0), "at {i}: {x} vs {y}");
    }
}

#[test]
fn pjrt_reduce_matches_scalar_exact_variants() {
    let Some(a) = arts() else { eprintln!("skipping: no artifacts"); return };
    let r = Reducer::Pjrt(a);
    for k in [2usize, 3, 4, 6, 8, 12, 16] {
        for n in [4096usize, 65536] {
            let rows = rand_rows(k, n, (k * n) as u64);
            let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
            let got = r.reduce(&refs).unwrap();
            let want = Reducer::Scalar.reduce(&refs).unwrap();
            close(&got, &want);
        }
    }
}

#[test]
fn pjrt_reduce_odd_shapes() {
    let Some(a) = arts() else { eprintln!("skipping: no artifacts"); return };
    let r = Reducer::Pjrt(a);
    // Fan-ins needing padding (5 -> 6, 9 -> 12) and lengths with tails.
    for (k, n) in [(5usize, 1000usize), (9, 70000), (7, 65536 + 4096 + 17), (2, 1), (17, 8192), (33, 5000)] {
        let rows = rand_rows(k, n, (k + n) as u64);
        let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let got = r.reduce(&refs).unwrap();
        let want = Reducer::Scalar.reduce(&refs).unwrap();
        close(&got, &want);
    }
}

#[test]
fn pjrt_sgd_matches_scalar() {
    let Some(a) = arts() else { eprintln!("skipping: no artifacts"); return };
    let r = Reducer::Pjrt(a);
    let n = 65536 + 123;
    let mut rng = Rng::new(3);
    let w0 = rng.f32_vec(n);
    let g = rng.f32_vec(n);
    let mut w_pjrt = w0.clone();
    r.sgd_update(&mut w_pjrt, &g, 0.01).unwrap();
    let mut w_scalar = w0;
    Reducer::Scalar.sgd_update(&mut w_scalar, &g, 0.01).unwrap();
    close(&w_pjrt, &w_scalar);
}

#[test]
fn manifest_integrity() {
    let Some(a) = arts() else { eprintln!("skipping: no artifacts"); return };
    assert_eq!(a.manifest.chunk_n, 65536);
    assert!(a.manifest.reduce_ks.contains(&2));
    assert!(a.manifest.reduce_ks.contains(&16));
}
