//! Property-based tests (in-repo `util::prop` harness, seeds reported on
//! failure and reproducible via PROP_SEED=<seed>).

use genmodel::exec;
use genmodel::gentree;
use genmodel::model::optimality::check_impossibility;
use genmodel::model::params::Environment;
use genmodel::plan::validate::{validate, Goal};
use genmodel::plan::{acps, cps, hcps, rhd, ring};
use genmodel::runtime::Reducer;
use genmodel::sim::{simulate_plan, SimConfig};
use genmodel::topo::{builders, Topology};
use genmodel::util::prop;
use genmodel::util::rng::Rng;

/// Random tree topology: 1–3 levels, arbitrary child counts.
fn random_topology(rng: &mut Rng) -> Topology {
    match rng.gen_range(0, 3) {
        0 => builders::single_switch(rng.gen_range(2, 24)),
        1 => {
            let mids = rng.gen_range(2, 5);
            let sizes: Vec<usize> = (0..mids).map(|_| rng.gen_range(1, 8)).collect();
            if sizes.iter().sum::<usize>() < 2 {
                builders::single_switch(4)
            } else {
                builders::asymmetric(&sizes, &[])
            }
        }
        _ => {
            let a: Vec<usize> = (0..rng.gen_range(1, 3)).map(|_| rng.gen_range(1, 6)).collect();
            let b: Vec<usize> = (0..rng.gen_range(1, 3)).map(|_| rng.gen_range(1, 6)).collect();
            if a.iter().chain(&b).sum::<usize>() < 2 {
                builders::single_switch(3)
            } else {
                builders::cross_dc(&a, &b)
            }
        }
    }
}

#[test]
fn prop_gentree_valid_on_random_topologies() {
    let env = Environment::paper();
    prop::run("gentree-valid", 48, |rng| {
        let topo = random_topology(rng);
        let s = 10f64.powf(rng.gen_range(4, 9) as f64);
        let out = gentree::generate(&topo, &env, s);
        validate(&out.plan, Goal::AllReduce)
            .map(|_| ())
            .map_err(|e| format!("{}: {e}", topo.name))
    });
}

#[test]
fn prop_gentree_never_loses_to_baselines_by_much() {
    let env = Environment::paper();
    prop::run("gentree-competitive", 16, |rng| {
        let topo = random_topology(rng);
        if topo.n_servers() < 2 {
            return Ok(());
        }
        let s = 1e7;
        let cfg = SimConfig::new(&topo);
        let ours = simulate_plan(
            &gentree::generate(&topo, &env, s).plan,
            s,
            &topo,
            &env,
            &cfg,
        )
        .total;
        let ring = simulate_plan(&ring::allreduce(topo.n_servers()), s, &topo, &env, &cfg).total;
        if ours > ring * 1.05 {
            return Err(format!("{}: GenTree {ours} vs Ring {ring}", topo.name));
        }
        Ok(())
    });
}

#[test]
fn prop_baseline_plans_valid_and_theorem2_holds() {
    prop::run("baselines-valid", 64, |rng| {
        let n = rng.gen_range(2, 33);
        let w_t = rng.gen_range(2, 12);
        let plans = vec![
            cps::allreduce(n),
            ring::allreduce(n),
            rhd::allreduce(n),
            genmodel::plan::reduce_broadcast::allreduce(n),
        ];
        for p in plans {
            let stats =
                validate(&p, Goal::AllReduce).map_err(|e| format!("{}: {e}", p.name))?;
            check_impossibility(&p, &stats, w_t)?;
        }
        Ok(())
    });
}

#[test]
fn prop_hcps_any_factorization_valid() {
    prop::run("hcps-valid", 48, |rng| {
        // Random factor list with product ≤ 64.
        let mut factors = Vec::new();
        let mut prod = 1usize;
        loop {
            let f = rng.gen_range(2, 6);
            if prod * f > 64 || (factors.len() >= 2 && rng.gen_range(0, 2) == 0) {
                break;
            }
            prod *= f;
            factors.push(f);
        }
        if factors.len() < 2 {
            factors = vec![2, rng.gen_range(2, 6)];
        }
        let p = hcps::allreduce(&factors);
        validate(&p, Goal::AllReduce)
            .map(|_| ())
            .map_err(|e| format!("{factors:?}: {e}"))
    });
}

#[test]
fn prop_acps_random_owner_maps_valid() {
    prop::run("acps-valid", 64, |rng| {
        let n = rng.gen_range(2, 12);
        let nb = rng.gen_range(1, 20);
        let owners: Vec<usize> = (0..nb).map(|_| rng.gen_range(0, n - 1)).collect();
        let p = acps::allreduce_with_owners(n, &owners);
        validate(&p, Goal::AllReduce)
            .map(|_| ())
            .map_err(|e| format!("n={n} owners={owners:?}: {e}"))
    });
}

#[test]
fn prop_executor_matches_oracle_on_random_plans() {
    prop::run("exec-oracle", 24, |rng| {
        let n = rng.gen_range(2, 10);
        let s = rng.gen_range(1, 5000);
        let plan = match rng.gen_range(0, 4) {
            0 => cps::allreduce(n),
            1 => ring::allreduce(n),
            2 => rhd::allreduce(n),
            _ => genmodel::plan::reduce_broadcast::allreduce(n),
        };
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| rng.f32_vec(s)).collect();
        let out = exec::execute_plan(&plan, &inputs, &Reducer::Scalar)
            .map_err(|e| format!("{e}"))?;
        exec::verify(&out, &inputs, 1e-3).map_err(|e| format!("{}: {e}", plan.name))
    });
}

#[test]
fn prop_mirror_of_random_valid_rs_is_valid_allgather() {
    prop::run("mirror-valid", 48, |rng| {
        let n = rng.gen_range(2, 16);
        let rs = match rng.gen_range(0, 3) {
            0 => cps::reduce_scatter(n),
            1 => ring::reduce_scatter(n),
            _ => rhd::reduce_scatter(n),
        };
        validate(&rs, Goal::ReduceScatter).map_err(|e| format!("{e}"))?;
        validate(&rs.into_allreduce(), Goal::AllReduce)
            .map(|_| ())
            .map_err(|e| format!("{e}"))
    });
}

#[test]
fn prop_simulator_sane_on_random_inputs() {
    let env = Environment::paper();
    prop::run("sim-sane", 24, |rng| {
        let topo = random_topology(rng);
        let n = topo.n_servers();
        if n < 2 {
            return Ok(());
        }
        let plan = if rng.gen_range(0, 2) == 0 {
            cps::allreduce(n)
        } else {
            ring::allreduce(n)
        };
        let s = 10f64.powf(rng.gen_range(3, 8) as f64);
        let r = simulate_plan(&plan, s, &topo, &env, &SimConfig::new(&topo));
        if !(r.total.is_finite() && r.total > 0.0) {
            return Err(format!("{}: total {}", topo.name, r.total));
        }
        if r.communication < 0.0 || r.calculation < 0.0 {
            return Err("negative component".into());
        }
        let sum: f64 = r.per_phase.iter().sum();
        if (sum - r.total).abs() > 1e-9 * r.total {
            return Err(format!("phase sum {sum} != total {}", r.total));
        }
        Ok(())
    });
}

#[test]
fn prop_plan_stats_bandwidth_conservation() {
    // Σ sent = Σ received for every plan (transfers conserve blocks).
    prop::run("bandwidth-conservation", 48, |rng| {
        let n = rng.gen_range(2, 20);
        let p = match rng.gen_range(0, 3) {
            0 => cps::allreduce(n),
            1 => ring::allreduce(n),
            _ => rhd::allreduce(n),
        };
        let stats = validate(&p, Goal::AllReduce).map_err(|e| format!("{e}"))?;
        let sent: usize = stats.sent_blocks.iter().sum();
        let recv: usize = stats.recv_blocks.iter().sum();
        if sent != recv {
            return Err(format!("sent {sent} != recv {recv}"));
        }
        Ok(())
    });
}
