//! Offline stand-in for the `anyhow` crate.
//!
//! The repository builds with no network access, so instead of the real
//! `anyhow` this vendored shim provides exactly the slice of its API the
//! crate uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Semantics match `anyhow` where it matters:
//!
//! * any `E: std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?` (possible because `Error` itself deliberately does
//!   **not** implement `std::error::Error`);
//! * context wraps are rendered `context: original` by `Display`;
//! * `Debug` renders the full message (so `fn main() -> Result<()>` error
//!   exits stay readable).

use std::error::Error as StdError;
use std::fmt;

/// Type-erased error: a display message plus (optionally) the source
/// error it was built from.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `anyhow`-style result alias with a defaultable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Build an error from a concrete error value, keeping it as source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Wrap with higher-level context (`context: original`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The retained source error, if this was built from one.
    pub fn source_ref(&self) -> Option<&(dyn StdError + Send + Sync + 'static)> {
        self.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` must not implement `std::error::Error`, or this blanket
// conversion would conflict with the reflexive `From<T> for T`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
        assert!(e.source_ref().is_some());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("key {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "key 7");
    }

    #[test]
    fn macros_format_and_bail() {
        fn inner(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(2).unwrap(), 2);
        assert_eq!(inner(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(inner(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("plain {}", 1);
        assert_eq!(e.to_string(), "plain 1");
    }
}
